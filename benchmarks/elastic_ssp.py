"""BSP vs SSP throughput under an injected straggler, plus elastic
host-kill recovery timing (see docs/benchmarks.md).

Two beyond-paper rows for the multi-host work:

  * **straggler sweep** — N independent hosts train through the SSP
    exchange lane (``DistributedRunner.run_epochs_ssp``) while the chaos
    injector delays a *rotating* victim 3x per round (host ``r % N`` sleeps
    during round ``r``).  Under BSP discipline (``staleness=0``) every
    round pays the full delay — the cohort moves at the slowest member's
    pace.  With ``staleness=2`` a delayed host no longer blocks its peers:
    each host only pays its *own* delays, which the rotation spreads
    1-in-N, so aggregate rows/sec recovers toward Nx.  The acceptance bar
    from the ISSUE — SSP >= 1.5x BSP — is asserted with ``--check`` (the
    nightly chaos leg runs that).
  * **kill recovery** — an :class:`repro.launch.elastic.ElasticController`
    run where one BSP host is SIGKILLed mid-stream; the row reports how
    long the controller took from death detection to respawning the
    shrunken generation (the live-migration latency), and that the resumed
    world finished cleanly.

Both rows use real subprocesses — the delays, the SIGKILL, and the
recovery are wall-clock facts, not simulations.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

from benchmarks._util import emit

HOSTS = 3
ROWS = 512
F = 16
EPOCHS = 9
DELAY = 0.3          # injected straggler sleep per victim round (seconds)

_HOST = """
import json, os, time
import numpy as np
import jax, jax.numpy as jnp

from repro.core.compat import make_mesh
from repro.core.exchange import ParamStore
from repro.core.runner import DistributedRunner
from repro.data import BatchIterator
from repro.testing import ChaosInjector, Fault

HOST = int(os.environ["REPRO_HOST_ID"])
N = int(os.environ["REPRO_NUM_HOSTS"])
S = int(os.environ["STALENESS"])
ROWS, F, E = %(ROWS)d, %(F)d, %(EPOCHS)d
DELAY = %(DELAY)f


def source(step):
    rng = np.random.RandomState(1000 * HOST + step)
    return {"data": rng.randn(ROWS, F + 1).astype(np.float32)}


def local_step(block, state, r):
    x, y = block[:, :F], block[:, F]
    g = x.T @ (x @ state - y) / block.shape[0]
    return state - 0.05 * g


mesh = make_mesh((len(jax.devices()),), ("data",))
runner = DistributedRunner(mesh=mesh, schedule="gather_broadcast")
store = ParamStore(os.environ["STORE_ROOT"], HOST, N, timeout=300.0,
                   keep=S + 2)
# the rotating straggler: host r %% N sleeps DELAY during round r
faults = [Fault(host=HOST, round=r, action="delay", seconds=DELAY)
          for r in range(E) if r %% N == HOST]
stream = ChaosInjector(faults, host_id=HOST, store=store).wrap_stream(
    BatchIterator(source, mesh=mesh))

# warm the jit before the clock starts so compile time is not in the row
runner.run_epochs_ssp(BatchIterator(source, mesh=mesh),
                      jnp.zeros((F,), jnp.float32), local_step, 1,
                      store=ParamStore(os.environ["STORE_ROOT"] + "_warm",
                                       HOST, N, timeout=300.0),
                      staleness=max(S, E), combine="mean")

t0 = time.perf_counter()
runner.run_epochs_ssp(stream, jnp.zeros((F,), jnp.float32), local_step, E,
                      store=store, staleness=S, combine="mean")
elapsed = time.perf_counter() - t0
print("RESULT::" + json.dumps({"host": HOST, "seconds": elapsed,
                               "rows": ROWS * E}))
"""

_ELASTIC_CHILD = """
import json, os
from repro.core import hostmesh
info = hostmesh.initialize_from_env()
import jax, jax.numpy as jnp
import numpy as np
from repro.core.compat import make_mesh
from repro.core.runner import CheckpointPolicy, DistributedRunner
from repro.data import BatchIterator
from repro.testing import ChaosInjector

ROWS, F, E = 64, 8, 6


def source(step):
    rng = np.random.RandomState(step)
    return {"data": rng.randn(ROWS, F + 1).astype(np.float32)}


def local_step(block, state, r):
    x, y = block[:, :F], block[:, F]
    g = x.T @ (x @ state - y) / block.shape[0]
    return state - 0.1 * g


mesh = make_mesh((len(jax.devices()),), ("data",))
runner = DistributedRunner(mesh=mesh, schedule="gather_broadcast")
stream = ChaosInjector.from_env().wrap_stream(BatchIterator(source, mesh=mesh))
ck = CheckpointPolicy(os.environ["CKPT_DIR"], every_epochs=1)
if os.environ.get("REPRO_RESUME") == "1":
    w = runner.resume(os.environ["CKPT_DIR"], stream,
                      jnp.zeros((F,), jnp.float32), local_step, E,
                      combine="mean", checkpoint=ck, allow_resize=True)
else:
    w = runner.run_epochs(stream, jnp.zeros((F,), jnp.float32), local_step, E,
                          combine="mean", chunks_per_epoch=1, checkpoint=ck)
print("done", flush=True)
"""


def _run_cohort(staleness: int, root: str) -> dict:
    """Spawn the straggler cohort at one staleness bound; aggregate
    rows/sec over the slowest member's wall clock."""
    prog = _HOST % {"ROWS": ROWS, "F": F, "EPOCHS": EPOCHS, "DELAY": DELAY}
    procs = []
    for h in range(HOSTS):
        env = dict(os.environ, PYTHONPATH="src",
                   XLA_FLAGS="--xla_force_host_platform_device_count=1",
                   REPRO_NUM_HOSTS=str(HOSTS), REPRO_HOST_ID=str(h),
                   STALENESS=str(staleness), STORE_ROOT=root)
        env.pop("REPRO_COORDINATOR", None)
        procs.append(subprocess.Popen(
            [sys.executable, "-c", prog], env=env,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__)))))
    results = []
    for h, p in enumerate(procs):
        out, err = p.communicate(timeout=560)
        if p.returncode != 0:
            raise RuntimeError(f"straggler host {h} failed:\n{err[-2000:]}")
        line = [l for l in out.splitlines() if l.startswith("RESULT::")][-1]
        results.append(json.loads(line[len("RESULT::"):]))
    seconds = max(r["seconds"] for r in results)
    rows = sum(r["rows"] for r in results)
    return {"staleness": staleness, "seconds": round(seconds, 3),
            "rows_per_sec": round(rows / seconds, 1)}


def _kill_recovery() -> dict:
    """One elastic BSP run with a mid-stream SIGKILL; report the restart
    latency the controller measured."""
    from repro.launch.elastic import ElasticController
    from repro.testing import Fault

    with tempfile.TemporaryDirectory(prefix="elastic_bench_") as tmp:
        controller = ElasticController(
            [sys.executable, "-c", _ELASTIC_CHILD], num_hosts=2,
            devices_per_host=2,
            env={"PYTHONPATH": "src",
                 "CKPT_DIR": os.path.join(tmp, "ck")},
            faults=[Fault(host=1, round=2, action="kill")],
            max_restarts=1, min_hosts=1, timeout=300.0)
        t0 = time.perf_counter()
        report = controller.run()
        total = time.perf_counter() - t0
    return {"generations": len(report.generations),
            "hosts": "->".join(str(g.num_hosts) for g in report.generations),
            "restart_seconds": round(report.restart_seconds[0], 3),
            "total_seconds": round(total, 1)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail unless SSP >= 1.5x BSP rows/sec (the ISSUE "
                         "acceptance bar; the nightly chaos leg passes this)")
    args = ap.parse_args()

    rows = []
    with tempfile.TemporaryDirectory(prefix="ssp_bench_") as tmp:
        bsp = _run_cohort(0, os.path.join(tmp, "bsp"))
        ssp = _run_cohort(2, os.path.join(tmp, "ssp"))
    ratio = ssp["rows_per_sec"] / bsp["rows_per_sec"]
    rows.append(dict(mode="bsp", **bsp))
    rows.append(dict(mode="ssp", **ssp))
    rows.append({"mode": "speedup", "ssp_over_bsp": round(ratio, 2),
                 "bar": 1.5, "met": ratio >= 1.5})
    rows.append(dict(mode="kill_recovery", **_kill_recovery()))
    emit("elastic_ssp", rows)
    if args.check and ratio < 1.5:
        raise SystemExit(
            f"SSP sustained only {ratio:.2f}x BSP under the rotating "
            f"straggler — below the 1.5x acceptance bar")


if __name__ == "__main__":
    main()
