"""Paper Figs. 2a / 3a: lines-of-code comparison.

The paper's usability claim: MLI implementations are MATLAB-short.  We count
the *algorithm-level* lines of our implementations (the code a developer
would write against the MLI API — gradient closure + optimizer call, or the
ALS loop) exactly as the paper counts its Fig. A4/A9 snippets, and print
them next to the paper's published numbers for the other systems.
"""
from __future__ import annotations

import inspect
import re

from benchmarks._util import emit

PAPER_NUMBERS = {
    # Fig 2a (logistic regression)
    "logreg": {"MLI (paper)": 55, "Vowpal Wabbit": 721, "MATLAB": 11},
    # Fig 3a (ALS)
    "als": {"MLI (paper)": 35, "GraphLab": 383, "Mahout": 865,
            "MATLAB-mex": 96, "MATLAB": 20},
}


def _count_source(obj) -> int:
    src = inspect.getsource(obj)
    lines = [l for l in src.splitlines()
             if l.strip() and not l.strip().startswith(("#", '"""', "'''"))]
    # drop docstring bodies
    out, in_doc = [], False
    for l in lines:
        s = l.strip()
        if s.startswith(('"""', "'''")):
            in_doc = not in_doc and not (s.endswith(('"""', "'''")) and len(s) > 3)
            continue
        if in_doc:
            if s.endswith(('"""', "'''")):
                in_doc = False
            continue
        out.append(l)
    return len(out)


def main() -> None:
    from repro.core.algorithms import als, logistic_regression
    from repro.core import optimizer as opt_mod

    logreg_loc = _count_source(logistic_regression.LogisticRegressionAlgorithm)
    sgd_loc = _count_source(opt_mod.StochasticGradientDescent)
    als_loc = _count_source(als.BroadcastALS) + _count_source(als._local_als)

    rows = [{"task": "logreg", "system": "MLI-JAX (this repo, algorithm)",
             "loc": logreg_loc},
            {"task": "logreg", "system": "MLI-JAX (this repo, SGD optimizer)",
             "loc": sgd_loc}]
    for sys_name, loc in PAPER_NUMBERS["logreg"].items():
        rows.append({"task": "logreg", "system": sys_name, "loc": loc})
    rows.append({"task": "als", "system": "MLI-JAX (this repo)", "loc": als_loc})
    for sys_name, loc in PAPER_NUMBERS["als"].items():
        rows.append({"task": "als", "system": sys_name, "loc": loc})
    emit("loc_table", rows)


if __name__ == "__main__":
    main()
