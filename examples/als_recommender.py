"""Paper §IV-B at laptop scale: BroadcastALS on tiled synthetic-Netflix
ratings with the paper's hyperparameters (rank 10, λ=.01, 10 iterations),
then top-k recommendations from the learned factors.

    PYTHONPATH=src python examples/als_recommender.py
"""
import numpy as np

from repro.core.algorithms.als import (ALSParameters, BroadcastALS,
                                       pack_csr_table)
from repro.data import synth_netflix_tiled


def main() -> None:
    M = synth_netflix_tiled(users=128, items=96, rank=6, tiles=2, density=0.15)
    m, n = M.shape
    r, c = np.nonzero(M)
    v = M[r, c]
    max_nnz = int(max((M != 0).sum(1).max(), (M != 0).sum(0).max()))
    print(f"ratings: {m} users x {n} items, {len(v)} observed, max_nnz={max_nnz}")

    data = pack_csr_table(r, c, v, m, max_nnz, num_shards=4)
    data_t = pack_csr_table(c, r, v, n, max_nnz, num_shards=4)

    # paper hyperparameters
    params = ALSParameters(rank=10, lam=0.01, max_iter=10, seed=0)
    model = BroadcastALS(params).fit(data, data_transposed=data_t)
    rmse = float(model.rmse(r, c, v))
    print(f"train RMSE after {params.max_iter} ALS sweeps: {rmse:.4f}")
    assert rmse < 0.5

    # recommend: highest predicted unseen items for user 0
    scores = np.asarray(model.U[0] @ model.V.T)
    seen = set(c[r == 0].tolist())
    ranked = [j for j in np.argsort(-scores) if j not in seen][:5]
    print(f"top-5 recommendations for user 0: {ranked}")
    print("als_recommender OK")


if __name__ == "__main__":
    main()
