"""Paper §IV-A at laptop scale: logistic regression via partition-local SGD
+ parameter averaging on dense 'featurized ImageNet'-style data, comparing
the paper's two collective schedules (MLI gather-broadcast vs VW allreduce)
and the paper's MATLAB-style full-batch GD.

    PYTHONPATH=src python examples/logreg_imagenet.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm, LogisticRegressionParameters)
from repro.core.collectives import CollectiveSchedule
from repro.core.numeric_table import MLNumericTable
from repro.data import synth_imagenet_features


def main() -> None:
    n, d = 2048, 1024            # paper: 200K x 160K on 32 machines
    X, y = synth_imagenet_features(n, d, seed=0)
    data = np.concatenate([y[:, None], X], axis=1).astype(np.float32)
    table = MLNumericTable.from_numpy(data, num_shards=8)
    print(f"dataset: {n} x {d}, 8 partitions")

    for name, params, floor in [
        ("MLI gather-broadcast (paper)", LogisticRegressionParameters(
            learning_rate=1.0, max_iter=30, local_batch_size=32,
            schedule=CollectiveSchedule.GATHER_BROADCAST), 0.9),
        ("VW-style allreduce", LogisticRegressionParameters(
            learning_rate=1.0, max_iter=30, local_batch_size=32,
            schedule=CollectiveSchedule.ALLREDUCE), 0.9),
        # the paper's MATLAB GD is a *runtime* reference; on this
        # uncentered ReLU-feature data it converges far slower than the
        # averaged SGD, so it gets a looser floor.
        ("full-batch GD (MATLAB ref)", LogisticRegressionParameters(
            learning_rate=2.0 / n, max_iter=50, solver="gd"), 0.5),
    ]:
        t0 = time.time()
        model = LogisticRegressionAlgorithm(params).fit(table)
        dt = time.time() - t0
        pred = np.asarray(model.predict(jnp.asarray(X))).ravel()
        acc = float((pred == y).mean())
        print(f"{name:32s} acc={acc:.3f}  wall={dt:.2f}s")
        assert acc >= floor, name
    print("logreg_imagenet OK")


if __name__ == "__main__":
    main()
