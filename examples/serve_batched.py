"""Batched serving example (deliverable b, serving kind): initialize a
smoke-scale model from the assigned-architecture pool, serve a batch of
requests through prefill + per-token decode, verify greedy determinism.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-1b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.models.transformer import init_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, batch_size=args.requests, max_seq=96)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"{args.arch}: served {len(done)} requests / {total} tokens "
          f"in {dt:.2f}s")
    # greedy decode must be deterministic
    again = engine.run([Request(prompt=reqs[0].prompt.copy(),
                                max_new_tokens=args.max_new)])
    assert again[0].out_tokens == done[0].out_tokens
    print("serve_batched OK")


if __name__ == "__main__":
    main()
