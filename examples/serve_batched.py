"""Continuous-batching serving example: initialize a smoke-scale model from
the assigned-architecture pool, serve a stream of MIXED-LENGTH requests
through the scheduler + ragged decode engine (admission queue, mid-decode
backfill), verify greedy determinism against the slot-at-a-time reference,
and serve a trained classic-ML model through the prediction service.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-1b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.models.transformer import init_model
from repro.serve import (ModelPredictor, Request, ServeEngine, SlotScheduler)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, batch_size=args.slots, max_seq=96)
    rng = np.random.default_rng(0)
    lens = [8 + 3 * (i % 4) for i in range(args.requests)]   # mixed lengths

    def make():
        r = np.random.default_rng(0)
        return [Request(prompt=r.integers(0, cfg.vocab_size, size=n)
                        .astype(np.int32), max_new_tokens=args.max_new)
                for n in lens]

    engine.warmup(prompt_lens=lens)
    sched = SlotScheduler(args.slots)
    t0 = time.time()
    done = engine.run(make(), scheduler=sched)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    rep = sched.report()
    print(f"{args.arch}: served {len(done)} mixed-length requests / {total} "
          f"tokens in {dt:.2f}s (backfills={rep['backfills']}, "
          f"queue depth max={rep['queue_depth_max']})")

    # greedy continuous batching must match slot-at-a-time exactly
    ref = [engine._run_one(r) for r in make()]
    assert all(a.out_tokens == b.out_tokens for a, b in zip(done, ref))

    # the same serving stack fronts the paper's classic Model contract
    from repro.core.algorithms.kmeans import KMeans, KMeansParameters
    from repro.core.numeric_table import MLNumericTable
    X = rng.normal(size=(64, 8)).astype(np.float32)
    model = KMeans(KMeansParameters(k=4, max_iter=4)).fit(
        MLNumericTable.from_numpy(X, num_shards=4))
    service = ModelPredictor(model, max_batch=16, num_shards=4)
    outs = service.predict_many([X[:10], X[10:40], X[40:]])
    assert sum(len(o) for o in outs) == 64
    print(f"predictor: {service.report()['batches']} microbatches, "
          f"{service.report()['rows_served']} rows")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
