"""Fig. A2 reproduction, end to end — raw text classification served from
ONE fitted object on an 8-device mesh:

    rawText -> NGrams(1, top) -> TfIdf -> Standardizer -> LogisticRegression
            -> ModelPredictor (raw-text requests through the microbatcher)

What this demonstrates (the acceptance story of the unified Estimator API):

  1. the whole program is one ``Pipeline`` fit through the shared
     ``DistributedRunner`` on a real 8-device data mesh;
  2. its predictions are fp-identical to the hand-composed function chain
     (fit each transformer, thread tables by hand, train the estimator);
  3. a raw-text request served through ``serve.ModelPredictor`` runs vocab
     lookup → tf-idf → standardize → predict inside the microbatching
     path and matches the offline predictions exactly;
  4. the label column rides through featurization untouched (the
     train/test-leakage and label-scaling traps are closed by design).

    PYTHONPATH=src python examples/text_pipeline.py
"""
import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8").strip()

import numpy as np

from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm,
    LogisticRegressionParameters,
)
from repro.core.compat import make_mesh
from repro.core.mltable import MLTable
from repro.data import synth_labeled_text
from repro.features import NGrams, Standardizer, TfIdf
from repro.pipeline import Pipeline
from repro.serve import ModelPredictor, PredictRequest


def main() -> None:
    rows = synth_labeled_text(n_docs=128, words_per_doc=20, seed=0)
    raw = MLTable.from_rows(rows, names=["label", "text"], num_partitions=4)
    print(f"corpus: {raw.num_rows} labeled docs")

    mesh = make_mesh((8,), ("data",))
    params = LogisticRegressionParameters(learning_rate=0.5, max_iter=8,
                                          local_batch_size=4)

    # ---- the pipeline object -------------------------------------------
    pipe = Pipeline([
        NGrams(n=1, top=64, column="text"),
        TfIdf(),
        Standardizer(),
        LogisticRegressionAlgorithm(params),
    ], mesh=mesh)
    fitted = pipe.fit(raw)
    table = fitted.transform(raw)
    X = np.asarray(table.data)
    print(f"featurized: {table.num_rows} x {table.num_cols - 1} features "
          f"on {table.num_shards} shards")

    # ---- the hand-composed chain (what users wrote before) -------------
    ngrams = NGrams(n=1, top=64, column="text").fit(raw)
    counts = ngrams.transform(raw).to_numeric(mesh=mesh)
    tfidf = TfIdf().fit(counts, default_skip=(0,))
    scaled_in = tfidf.transform(counts)
    standardizer = Standardizer().fit(scaled_in, default_skip=(0,))
    final = standardizer.transform(scaled_in)
    hand_model = LogisticRegressionAlgorithm(params).fit(final)

    pipe_preds = np.asarray(fitted.model.predict(table.data[:, 1:]))
    hand_preds = np.asarray(hand_model.predict(final.data[:, 1:]))
    assert np.array_equal(pipe_preds, hand_preds), \
        "pipeline must be fp-identical to the hand-composed chain"
    assert np.array_equal(
        np.asarray(fitted.model.weights), np.asarray(hand_model.weights))
    acc = float(np.mean(pipe_preds == X[:, 0]))
    print(f"pipeline == hand-composed chain (fp-identical); "
          f"train accuracy {acc:.3f}")

    # ---- serving raw text ----------------------------------------------
    # A raw-text request runs vocab lookup (host tier) then the device
    # chain tf-idf -> standardize -> predict inside ONE compiled
    # microbatch program.
    service = ModelPredictor(fitted, max_batch=16)
    texts = [t for _, t in rows[:40]]
    reqs = [service.submit(PredictRequest(features=t)) for t in texts]
    service.flush()
    served = np.asarray([float(r.result[0]) for r in reqs])
    assert np.array_equal(served, pipe_preds[:40]), \
        "served raw-text predictions must match the offline pipeline"
    print(f"served {len(reqs)} raw-text requests in "
          f"{service.batches} microbatches; parity with offline: True")
    print(f"sample: {texts[0][:42]!r}… -> class {served[0]:.0f} "
          f"(label {rows[0][0]:.0f})")
    print("text_pipeline OK")


if __name__ == "__main__":
    main()
