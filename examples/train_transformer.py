"""End-to-end driver (deliverable b): train a ~100M-param transformer for a
few hundred steps on the planted-bigram LM stream, with checkpointing and a
loss-decrease assertion.  This is the beyond-paper substrate exercising the
same Optimizer-as-first-class-citizen contract at transformer scale.

Default config is a ~100M-param qwen2-family model (d=512, 8 layers, vocab
8192).  ~300 steps on this CPU container takes tens of minutes; use
--steps/--dim to shrink.

    PYTHONPATH=src python examples/train_transformer.py --steps 300
"""
import argparse
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import BatchIterator, SyntheticLMDataset
from repro.optim.optimizers import adamw
from repro.train.step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("qwen2-1.5b").scaled(
        num_layers=args.layers, d_model=args.dim, num_heads=8, num_kv_heads=2,
        d_ff=4 * args.dim, vocab_size=args.vocab, dtype="float32",
        remat=False, q_chunk=128, max_seq_len=2048)
    opt = adamw(lr=1e-3, warmup=20, total_steps=args.steps, weight_decay=0.01)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state.params))
    print(f"model: {args.layers}L d={args.dim} vocab={args.vocab} "
          f"-> {n_params/1e6:.1f}M params")

    step_fn = make_train_step(cfg, opt)
    ds = SyntheticLMDataset(vocab_size=args.vocab, seq_len=args.seq,
                            batch_size=args.batch, noise=0.02)
    it = BatchIterator(ds.batch)
    losses = []
    t0 = time.time()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        for step in range(args.steps):
            state, m = step_fn(state, next(it))
            losses.append(float(m["loss"]))
            if step % 20 == 0 or step == args.steps - 1:
                toks = args.batch * args.seq * (step + 1)
                print(f"step {step:4d} loss {losses[-1]:.4f} "
                      f"tok/s {toks/(time.time()-t0):,.0f}")
            if step == args.steps // 2:
                save_checkpoint(ckpt_dir, step, state)
        # restart-based recovery demo
        restored, at = restore_checkpoint(ckpt_dir, state)
        print(f"checkpoint restores at step {at}: OK")

    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first * 0.8, "loss must decrease on learnable data"
    print("train_transformer OK")


if __name__ == "__main__":
    main()
