"""Quickstart — the paper's Fig. A2 pipeline, end to end:

    load text -> nGrams(2, top=...) -> tfIdf -> KMeans(k)

All training is executed by the shared DistributedRunner (see
docs/architecture.md) on a real 4-device data-parallel mesh (emulated host
devices, forced below before jax initializes).  The k-means schedule knob
selects the §IV-A collective schedule the runner uses for the per-round
combine — each schedule lowers to different HLO collectives on the mesh —
and switching it must not change the model, which this script demonstrates
by training under all three schedules and comparing inertia.

The second half shows the streaming + fault-tolerance path: the same
k-means trained from per-epoch minibatch windows (data never fully
resident), checkpointed every epoch, "preempted" half-way, and resumed
from the snapshot — the resumed model matches the uninterrupted one
exactly.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4").strip()

import numpy as np

from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.collectives import CollectiveSchedule
from repro.core.compat import make_mesh
from repro.core.mltable import MLTable
from repro.core.runner import CheckpointPolicy, DistributedRunner
from repro.data import BatchIterator, synth_text_corpus
from repro.features.text import n_grams, tf_idf


def main() -> None:
    # mc.textFile(...) — one string column per line
    docs = synth_text_corpus(n_docs=64, words_per_doc=40)
    raw = MLTable.from_text(docs, num_partitions=4)
    print(f"loaded {raw.num_rows} docs in {raw.num_partitions} partitions")

    # feature extraction: top-64 bigram counts -> tf-idf
    featurized = tf_idf(n_grams(raw, n=2, top=64))
    print(f"featurized: {featurized.num_rows} x {featurized.num_cols}")

    # commit to the device tier on a 4-device data mesh; the runner owns
    # partitioning + combination
    mesh = make_mesh((4,), ("data",))
    table = featurized.to_numeric(mesh=mesh)
    print(f"execution layer: {DistributedRunner.for_table(table)}")

    # the schedule is a knob, not an algorithm change: all three collective
    # schedules lower to different mesh collectives but must produce the
    # same clustering
    inertia, model = {}, None
    for sched in CollectiveSchedule:
        params = KMeansParameters(k=4, max_iter=10, seed=0, schedule=sched)
        trained = KMeans.train(table, params)
        if model is None:                       # schedules agree: keep one
            model = trained
        inertia[sched.value] = float(trained.inertia(table.data))
        print(f"k-means[{sched.value:>16}] inertia: {inertia[sched.value]:.4f}")
    spread = max(inertia.values()) - min(inertia.values())
    assert spread < 1e-3 * max(1.0, max(inertia.values())), inertia

    labels = np.asarray(model.predict(table.data))
    sizes = np.bincount(labels, minlength=4)
    print(f"k-means cluster sizes: {sizes.tolist()}")
    assert sizes.sum() == 64

    # ---- streaming + fault tolerance -----------------------------------
    # The same clustering fed as per-epoch minibatch windows: the table
    # never needs to be resident; each epoch the runner pulls one sharded
    # window and scans its chunks on-device.  A CheckpointPolicy snapshots
    # (state, epoch, stream step) each epoch, so a killed run resumes
    # bit-for-bit.
    X = np.asarray(table.data)

    def window_source(step: int) -> dict:
        # replay the featurized rows as the stream; a production source
        # would read shard files keyed by step
        return {"data": X}

    epochs, half = 6, 3
    params = KMeansParameters(k=4, max_iter=epochs, seed=0)
    straight = KMeans.train_stream(BatchIterator(window_source, mesh=mesh),
                                   params, chunks_per_epoch=2)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        policy = CheckpointPolicy(ckpt_dir, every_epochs=1)
        # "preemption": the first run only survives to the half-way epoch
        KMeans.train_stream(BatchIterator(window_source, mesh=mesh), params,
                            num_epochs=half, chunks_per_epoch=2,
                            checkpoint=policy)
        resumed = KMeans.train_stream(BatchIterator(window_source, mesh=mesh),
                                      params, checkpoint=policy, resume=True)
    drift = float(np.abs(np.asarray(straight.centroids)
                         - np.asarray(resumed.centroids)).max())
    print(f"streaming kill+resume drift vs uninterrupted: {drift:.2e}")
    assert drift == 0.0, "resume must be bit-for-bit on the same mesh"
    print("quickstart OK")


if __name__ == "__main__":
    main()
