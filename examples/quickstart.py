"""Quickstart — the paper's Fig. A2 pipeline as ONE fitted object:

    Pipeline([NGrams(2, top=…), TfIdf(), KMeans(k)]).fit(rawTextTable)

The pipeline is the unit of everything downstream (docs/architecture.md,
"one contract, five execution modes"): the same object fits resident or
streaming through the shared DistributedRunner on a real 4-device mesh
(emulated host devices, forced below before jax initializes), its
featurizer statistics are fit ONCE and replayed on any rows, and its
checkpoint is one atomic artifact (vocabulary + IDF weights + centroids +
stream position).

Three demonstrations:
  1. the k-means schedule knob selects the §IV-A collective schedule —
     switching it must not change the model (inertia compared across all
     three);
  2. fitted-transformer replay: transforming the corpus row-by-row equals
     transforming it as one table (no hidden corpus refit);
  3. streaming + fault tolerance: the same pipeline trained from per-epoch
     minibatch windows, checkpointed every epoch, "preempted" half-way,
     and resumed — bit-for-bit equal to the uninterrupted run, featurizers
     restored from the snapshot rather than refit.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4").strip()

import numpy as np

from repro.core.algorithms.kmeans import KMeans
from repro.core.collectives import CollectiveSchedule
from repro.core.compat import make_mesh
from repro.core.mltable import MLTable
from repro.core.runner import CheckpointPolicy, DistributedRunner
from repro.data import synth_text_corpus
from repro.features import NGrams, TfIdf
from repro.pipeline import Pipeline


def main() -> None:
    # mc.textFile(...) — one string column per line
    docs = synth_text_corpus(n_docs=64, words_per_doc=40)
    raw = MLTable.from_text(docs, num_partitions=4)
    print(f"loaded {raw.num_rows} docs in {raw.num_partitions} partitions")

    mesh = make_mesh((4,), ("data",))

    # the schedule is a knob, not an algorithm change: all three collective
    # schedules lower to different mesh collectives but must produce the
    # same clustering
    inertia, fitted, table = {}, None, None
    for sched in CollectiveSchedule:
        pipe = Pipeline([NGrams(n=2, top=64), TfIdf(),
                         KMeans(k=4, max_iter=10, seed=0, schedule=sched)],
                        mesh=mesh)
        trained = pipe.fit(raw)
        featurized = trained.transform(raw)
        if fitted is None:                      # schedules agree: keep one
            fitted, table = trained, featurized
        inertia[sched.value] = float(trained.model.inertia(featurized.data))
        print(f"k-means[{sched.value:>16}] inertia: {inertia[sched.value]:.4f}")
    spread = max(inertia.values()) - min(inertia.values())
    assert spread < 1e-3 * max(1.0, max(inertia.values())), inertia
    print(f"execution layer: {DistributedRunner.for_table(table)}")

    # fitted replay: featurizing the corpus row-by-row (the serving path)
    # equals featurizing it as one table — the vocabulary and IDF weights
    # were fit once and only replayed
    row_preds = np.asarray(fitted.predict(docs))
    tab_preds = np.asarray(fitted.model.predict(table.data))
    assert np.array_equal(row_preds, tab_preds)
    sizes = np.bincount(tab_preds, minlength=4)
    print(f"k-means cluster sizes: {sizes.tolist()} "
          f"(row-by-row == whole-table: True)")

    # ---- streaming + fault tolerance -----------------------------------
    # The same pipeline fed as per-epoch minibatch windows: each epoch the
    # runner pulls one sharded window of the featurized table and scans its
    # chunks on-device.  Every snapshot is ONE atomic file carrying the
    # featurizer statistics + centroids + stream position, so a killed run
    # resumes bit-for-bit with the featurizers *restored*, never refit.
    def make_pipe():
        return Pipeline([NGrams(n=2, top=64), TfIdf(),
                         KMeans(k=4, max_iter=6, seed=0)], mesh=mesh)

    epochs, half = 6, 3
    straight = make_pipe().fit_stream(raw, num_epochs=epochs,
                                      chunks_per_epoch=2)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        # "preemption": the first run only survives to the half-way epoch
        make_pipe().fit_stream(raw, num_epochs=half, chunks_per_epoch=2,
                               checkpoint=CheckpointPolicy(ckpt_dir,
                                                           every_epochs=1))
        resumed = make_pipe().fit_stream(
            raw, num_epochs=epochs, chunks_per_epoch=2,
            checkpoint=CheckpointPolicy(ckpt_dir, every_epochs=1),
            resume=True)
    drift = float(np.abs(np.asarray(straight.model.centroids)
                         - np.asarray(resumed.model.centroids)).max())
    print(f"streaming kill+resume drift vs uninterrupted: {drift:.2e}")
    assert drift == 0.0, "resume must be bit-for-bit on the same mesh"
    assert resumed["ngrams"].vocab == straight["ngrams"].vocab
    print("quickstart OK")


if __name__ == "__main__":
    main()
