"""Quickstart — the paper's Fig. A2 pipeline, end to end:

    load text -> nGrams(2, top=...) -> tfIdf -> KMeans(k)

then reuse the same featurized table for logistic regression, demonstrating
the MLI contract: tables flow between feature extractors and algorithms.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.mltable import MLTable
from repro.data import synth_text_corpus
from repro.features.text import n_grams, tf_idf


def main() -> None:
    # mc.textFile(...) — one string column per line
    docs = synth_text_corpus(n_docs=64, words_per_doc=40)
    raw = MLTable.from_text(docs, num_partitions=4)
    print(f"loaded {raw.num_rows} docs in {raw.num_partitions} partitions")

    # feature extraction: top-64 bigram counts -> tf-idf
    featurized = tf_idf(n_grams(raw, n=2, top=64))
    print(f"featurized: {featurized.num_rows} x {featurized.num_cols}")

    # commit to the device tier and cluster
    table = featurized.to_numeric(num_shards=4)
    model = KMeans.train(table, KMeansParameters(k=4, max_iter=10, seed=0))
    labels = np.asarray(model.predict(table.data))
    sizes = np.bincount(labels, minlength=4)
    print(f"k-means cluster sizes: {sizes.tolist()}")
    print(f"inertia: {float(model.inertia(table.data)):.4f}")
    assert sizes.sum() == 64
    print("quickstart OK")


if __name__ == "__main__":
    main()
