"""Quickstart — the paper's Fig. A2 pipeline, end to end:

    load text -> nGrams(2, top=...) -> tfIdf -> KMeans(k)

All training is executed by the shared DistributedRunner (see
docs/architecture.md) on a real 4-device data-parallel mesh (emulated host
devices, forced below before jax initializes).  The k-means schedule knob
selects the §IV-A collective schedule the runner uses for the per-round
combine — each schedule lowers to different HLO collectives on the mesh —
and switching it must not change the model, which this script demonstrates
by training under all three schedules and comparing inertia.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4").strip()

import numpy as np

from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.collectives import CollectiveSchedule
from repro.core.compat import make_mesh
from repro.core.mltable import MLTable
from repro.core.runner import DistributedRunner
from repro.data import synth_text_corpus
from repro.features.text import n_grams, tf_idf


def main() -> None:
    # mc.textFile(...) — one string column per line
    docs = synth_text_corpus(n_docs=64, words_per_doc=40)
    raw = MLTable.from_text(docs, num_partitions=4)
    print(f"loaded {raw.num_rows} docs in {raw.num_partitions} partitions")

    # feature extraction: top-64 bigram counts -> tf-idf
    featurized = tf_idf(n_grams(raw, n=2, top=64))
    print(f"featurized: {featurized.num_rows} x {featurized.num_cols}")

    # commit to the device tier on a 4-device data mesh; the runner owns
    # partitioning + combination
    mesh = make_mesh((4,), ("data",))
    table = featurized.to_numeric(mesh=mesh)
    print(f"execution layer: {DistributedRunner.for_table(table)}")

    # the schedule is a knob, not an algorithm change: all three collective
    # schedules lower to different mesh collectives but must produce the
    # same clustering
    inertia, model = {}, None
    for sched in CollectiveSchedule:
        params = KMeansParameters(k=4, max_iter=10, seed=0, schedule=sched)
        trained = KMeans.train(table, params)
        if model is None:                       # schedules agree: keep one
            model = trained
        inertia[sched.value] = float(trained.inertia(table.data))
        print(f"k-means[{sched.value:>16}] inertia: {inertia[sched.value]:.4f}")
    spread = max(inertia.values()) - min(inertia.values())
    assert spread < 1e-3 * max(1.0, max(inertia.values())), inertia

    labels = np.asarray(model.predict(table.data))
    sizes = np.bincount(labels, minlength=4)
    print(f"k-means cluster sizes: {sizes.tolist()}")
    assert sizes.sum() == 64
    print("quickstart OK")


if __name__ == "__main__":
    main()
