"""Model search walkthrough — the MLbase end goal on top of MLI.

A grid over logistic-regression regularization × step size, trained as
device-stacked trials on a real 8-device data-parallel mesh (emulated
host devices, forced below before jax initializes):

  1. enumerate the grid (`tune.grid` — deterministic ordering);
  2. 3-fold cross-validation as row-index views (`tune.cv` — no data
     copy; the train view streams one window per epoch, the validation
     view is scored in place);
  3. all 8 configs advance together: their learning rates and L2
     penalties are *traced* values stacked along a leading trial axis,
     so ONE jitted round and ONE collective per round train the whole
     grid (`DistributedRunner.run_stacked_epochs`);
  4. shard-aware scoring (`eval.metrics.accuracy`) under the same
     collective schedule;
  5. the winner is compared against training that single config alone —
     the stacked search reproduces per-config training exactly.

    PYTHONPATH=src python examples/model_search.py
"""
import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8").strip()

import numpy as np


def main() -> None:
    import jax

    from repro.core.algorithms.logistic_regression import (
        LogisticRegressionAlgorithm, LogisticRegressionParameters)
    from repro.core.compat import make_mesh
    from repro.core.numeric_table import MLNumericTable
    from repro.eval import metrics
    from repro.tune import ModelSearch, fold_view, grid, holdout_split

    # -- a synthetic classification table on an 8-device mesh ------------
    rng = np.random.default_rng(0)
    rows, d = 256, 16
    X = rng.normal(size=(rows, d)).astype(np.float32)
    w_true = np.linspace(-1, 1, d).astype(np.float32)
    y = (X @ w_true > 0).astype(np.float32)
    mesh = make_mesh((len(jax.devices()),), ("data",))
    table = MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                      mesh=mesh)
    print(f"table: {table.num_rows} x {table.num_cols} on "
          f"{len(jax.devices())} devices")

    # -- the grid: 4 step sizes x 2 regularizers = 8 candidates ----------
    configs = grid({"learning_rate": [0.05, 0.1, 0.2, 0.4],
                    "l2": [0.0, 0.01]})
    print(f"grid: {len(configs)} configs (stacked into one vmapped round)")

    # -- device-stacked search with 3-fold CV ----------------------------
    search = ModelSearch("logreg", configs, num_epochs=6, chunks_per_epoch=2,
                         folds=3, execution="stacked", schedule="allreduce",
                         seed=0)
    result = search.run(table)
    for t in result.trials:
        print(f"  trial {t.index}: lr={t.config['learning_rate']:<5} "
              f"l2={t.config['l2']:<5} cv-accuracy={t.score:.4f}")
    best = result.best
    print(f"best: {best.config} (cv-accuracy {best.score:.4f})")
    # every trial carries its trained Model (spec.finalize); the winner is
    # ready to predict without a refit
    print(f"best model ready: {type(best.model).__name__}, "
          f"|w| = {float(abs(best.model.weights).sum()):.3f}")

    # -- the stacked winner matches training that config alone -----------
    tr, va = holdout_split(table.num_rows, 0.25, seed=0)
    solo = LogisticRegressionAlgorithm(
        LogisticRegressionParameters(
            learning_rate=best.config["learning_rate"],
            l2=best.config["l2"], max_iter=6,
            schedule="allreduce")).fit(fold_view(table, tr))
    val = fold_view(table, va)
    acc = float(metrics.accuracy(
        val, lambda Xb: solo.predict(Xb), schedule="allreduce"))
    print(f"single-model refit of the winner: holdout accuracy {acc:.4f}")


if __name__ == "__main__":
    main()
