"""Non-gradient algorithms through the same MLI contract (paper §IV:
'naturally extend to a diverse group of ML algorithms'):

    PCA    — partition-local Gram blocks -> explicit global sum -> local eig
    GNB    — one matrixBatchMap pass of per-class sufficient statistics

then chained: project with PCA, classify in the reduced space.

    PYTHONPATH=src python examples/pca_naive_bayes.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms.naive_bayes import (GaussianNaiveBayes,
                                               NaiveBayesParameters)
from repro.core.algorithms.pca import PCA, PCAParameters
from repro.core.numeric_table import MLNumericTable


def main() -> None:
    rng = np.random.default_rng(0)
    C, n_per, d = 3, 256, 16
    centers = rng.normal(size=(C, d)) * 3
    X = np.concatenate([rng.normal(size=(n_per, d)) + centers[c]
                        for c in range(C)]).astype(np.float32)
    y = np.repeat(np.arange(C), n_per).astype(np.float32)

    # PCA on the unlabeled features
    feats = MLNumericTable.from_numpy(X, num_shards=4)
    pca = PCA(PCAParameters(n_components=4)).fit(feats)
    print(f"explained variance: "
          f"{np.asarray(pca.explained_variance).round(2).tolist()}")
    Z = np.asarray(pca.transform(jnp.asarray(X)))

    # Naive Bayes in the reduced space
    table = MLNumericTable.from_numpy(
        np.concatenate([y[:, None], Z], 1).astype(np.float32), num_shards=4)
    nb = GaussianNaiveBayes(NaiveBayesParameters(num_classes=C)).fit(table)
    pred = np.asarray(nb.predict(jnp.asarray(Z)))
    acc = float((pred == y).mean())
    print(f"PCA({d}->{4}) + GaussianNB accuracy: {acc:.3f}")
    assert acc > 0.9
    print("pca_naive_bayes OK")


if __name__ == "__main__":
    main()
