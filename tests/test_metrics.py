"""`eval/metrics` coverage: each shard-aware metric against its plain
numpy reference, shard-count invariance (the combine is algebraically a
global sum), and the stacked (K, rows) form that scores K trials in one
pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numeric_table import MLNumericTable
from repro.eval import accuracy, log_loss, rmse, silhouette_lite


@pytest.fixture
def clf_table(rng):
    X = rng.normal(size=(64, 6)).astype(np.float32)
    w = np.linspace(-1, 1, 6).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    data = np.concatenate([y[:, None], X], 1)
    return X, y, w, data


def test_accuracy_matches_numpy(clf_table):
    X, y, w, data = clf_table
    wj = jnp.asarray(w) * 0.5
    pred = (jax.nn.sigmoid(X @ (w * 0.5)) > 0.5).astype(np.float32)
    want = float(np.mean(pred == y))
    for shards in (1, 4, 8):
        table = MLNumericTable.from_numpy(data, num_shards=shards)
        got = float(accuracy(
            table,
            lambda Xb: (jax.nn.sigmoid(Xb @ wj) > 0.5).astype(jnp.float32)))
        assert got == pytest.approx(want, abs=1e-6)


def test_log_loss_matches_numpy(clf_table):
    X, y, w, data = clf_table
    wj = jnp.asarray(w)
    p = 1.0 / (1.0 + np.exp(-(X @ w)))
    p = np.clip(p, 1e-7, 1 - 1e-7)
    want = float(np.mean(-(y * np.log(p) + (1 - y) * np.log1p(-p))))
    table = MLNumericTable.from_numpy(data, num_shards=4)
    got = float(log_loss(table, lambda Xb: jax.nn.sigmoid(Xb @ wj)))
    assert got == pytest.approx(want, rel=1e-5)


def test_rmse_matches_numpy(rng):
    X = rng.normal(size=(48, 5)).astype(np.float32)
    w = np.arange(1, 6, dtype=np.float32) / 5
    y = (X @ w + 0.1 * rng.normal(size=48)).astype(np.float32)
    data = np.concatenate([y[:, None], X], 1)
    want = float(np.sqrt(np.mean((X @ w - y) ** 2)))
    wj = jnp.asarray(w)
    table = MLNumericTable.from_numpy(data, num_shards=4)
    assert float(rmse(table, lambda Xb: Xb @ wj)) == pytest.approx(
        want, rel=1e-5)


def test_stacked_predictions_score_all_trials_in_one_pass(clf_table):
    X, y, w, data = clf_table
    table = MLNumericTable.from_numpy(data, num_shards=4)
    W = jnp.stack([jnp.asarray(w), jnp.zeros(6), -jnp.asarray(w)])

    def predict(Xb):
        return (jax.nn.sigmoid(Xb @ W.T).T > 0.5).astype(jnp.float32)

    scores = np.asarray(accuracy(table, predict))
    assert scores.shape == (3,)
    # each stacked entry equals the per-model score
    for i, wi in enumerate(np.asarray(W)):
        wij = jnp.asarray(wi)
        solo = float(accuracy(
            table,
            lambda Xb: (jax.nn.sigmoid(Xb @ wij) > 0.5).astype(jnp.float32)))
        assert scores[i] == pytest.approx(solo, abs=1e-6)
    # the true weights classify the synthetic labels perfectly; negated
    # weights get them all wrong
    assert scores[0] == pytest.approx(1.0)
    assert scores[2] == pytest.approx(0.0)


def test_silhouette_lite_separated_beats_overlapping(rng):
    tight = np.concatenate([rng.normal(size=(32, 4), scale=0.2),
                            8 + rng.normal(size=(32, 4), scale=0.2)])
    table = MLNumericTable.from_numpy(tight.astype(np.float32), num_shards=4)
    good = jnp.asarray(np.stack([np.zeros(4), np.full(4, 8.0)]), jnp.float32)
    bad = jnp.asarray(np.stack([np.full(4, 3.9), np.full(4, 4.1)]), jnp.float32)
    s_good = float(silhouette_lite(table, good))
    s_bad = float(silhouette_lite(table, bad))
    assert s_good > 0.9
    assert s_good > s_bad
    # stacked centroid sets score identically to their solo runs
    stacked = np.asarray(silhouette_lite(table, jnp.stack([good, bad])))
    assert stacked[0] == pytest.approx(s_good, abs=1e-6)
    assert stacked[1] == pytest.approx(s_bad, abs=1e-6)


def test_metrics_respect_fold_views(clf_table):
    """Scoring a fold view only sees the view's rows."""
    from repro.tune.cv import fold_view

    X, y, w, data = clf_table
    table = MLNumericTable.from_numpy(data, num_shards=4)
    idx = np.arange(16)
    view = fold_view(table, idx)
    wj = jnp.asarray(w)
    got = float(rmse(view, lambda Xb: Xb @ wj))
    want = float(np.sqrt(np.mean((X[idx] @ w - y[idx]) ** 2)))
    assert got == pytest.approx(want, rel=1e-5)
