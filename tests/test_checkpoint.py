"""Property tests for `repro.checkpoint.store` — the persistence layer the
streaming fault-tolerance story (DistributedRunner.run_epochs / resume)
stands on.

Pinned properties:
  * save → restore round-trips **values, dtypes, and structure** for any
    nested dict/tuple/dataclass pytree, including extension dtypes
    (bfloat16) that numpy would otherwise load back as raw void arrays;
  * host-side metadata rides in the same atomic file and round-trips;
  * ``latest_step`` ignores ``.tmp`` leftovers of a killed write and any
    non-checkpoint files;
  * restoring into a mismatched template raises with the offending keys
    named;
  * ``keep`` pruning retains exactly the newest snapshots.
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import (
    latest_step,
    load_metadata,
    prune_checkpoints,
    restore_checkpoint,
    restore_with_metadata,
    save_checkpoint,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    """Stand-in for an algorithm's checkpointable state."""
    weights: jnp.ndarray
    moment: jnp.ndarray


DTYPES = ("float32", "int32", "float16", "bfloat16")


def _leaf(dtype: str, shape, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    if dtype == "int32":
        return jnp.asarray(rng.integers(-1000, 1000, size=shape), jnp.int32)
    return jnp.asarray(rng.normal(size=shape), jnp.dtype(dtype))


def _make_tree(dt_a: str, dt_b: str, dt_c: str, rows: int, seed: int):
    """Nested dict / tuple / dataclass pytree with mixed-dtype leaves."""
    return {
        "state": TrainState(weights=_leaf(dt_a, (rows, 3), seed),
                            moment=_leaf(dt_b, (rows,), seed + 1)),
        "counters": (_leaf(dt_c, (2, 2), seed + 2),
                     _leaf("int32", (), seed + 3)),
    }


@settings(max_examples=20, deadline=None)
@given(dt_a=st.sampled_from(DTYPES), dt_b=st.sampled_from(DTYPES),
       dt_c=st.sampled_from(DTYPES), rows=st.integers(1, 16),
       step=st.integers(0, 10**6), seed=st.integers(0, 2**16))
def test_roundtrip_preserves_values_dtypes_structure(dt_a, dt_b, dt_c, rows,
                                                     step, seed):
    tree = _make_tree(dt_a, dt_b, dt_c, rows, seed)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, step, tree)
        template = jax.tree.map(jnp.zeros_like, tree)
        restored, got_step = restore_checkpoint(d, template)
        assert got_step == step
    assert (jax.tree_util.tree_structure(restored)
            == jax.tree_util.tree_structure(tree))
    for got, want in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        assert got.dtype == want.dtype
        assert got.shape == want.shape
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(epoch=st.integers(0, 1000), stream_step=st.integers(0, 10**6),
       rng_hi=st.integers(0, 2**31 - 1))
def test_metadata_roundtrips_in_same_file(epoch, stream_step, rng_hi):
    """Host-side loop counters (epoch, stream position, rng key) ride in
    the same atomic checkpoint file and come back exactly."""
    meta = {"epoch": epoch, "stream_step": stream_step, "rng": [rng_hi, 7],
            "schedule": "allreduce"}
    tree = {"w": jnp.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, epoch, tree, metadata=meta)
        _, step, got = restore_with_metadata(d, {"w": jnp.zeros(4)})
        assert step == epoch
        assert got == meta
        assert load_metadata(d) == meta


@settings(max_examples=20, deadline=None)
@given(steps=st.lists(st.integers(0, 500), min_size=1, max_size=6),
       junk_step=st.integers(501, 999))
def test_latest_step_ignores_tmp_and_foreign_files(steps, junk_step):
    """A kill mid-write leaves ``.tmp`` partials behind; they and any
    non-checkpoint files must never be picked up as the latest snapshot."""
    tree = {"w": jnp.zeros(2)}
    with tempfile.TemporaryDirectory() as d:
        for s in steps:
            save_checkpoint(d, s, tree)
        # dead partial from a killed write, with a HIGHER step than any real
        # checkpoint, plus assorted non-checkpoint files
        open(os.path.join(d, f"step_{junk_step}.npz.tmp"), "wb").close()
        open(os.path.join(d, "notes.txt"), "w").close()
        open(os.path.join(d, "xstep_7777.npz"), "wb").close()
        open(os.path.join(d, "step_.npz"), "wb").close()
        assert latest_step(d) == max(steps)
        restored, got = restore_checkpoint(d, {"w": jnp.ones(2)})
        assert got == max(steps)


def test_latest_step_empty_and_missing(tmp_ckpt_dir):
    assert latest_step(tmp_ckpt_dir) is None
    assert latest_step(os.path.join(tmp_ckpt_dir, "nope")) is None
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmp_ckpt_dir, {"w": jnp.zeros(1)})


def test_mismatched_tree_raises_with_key_names(tmp_ckpt_dir):
    save_checkpoint(tmp_ckpt_dir, 1, {"w": jnp.zeros(3), "b": jnp.zeros(1)})
    with pytest.raises(ValueError) as ei:
        restore_checkpoint(tmp_ckpt_dir, {"w": jnp.zeros(3),
                                          "extra_moment": jnp.zeros(3)})
    msg = str(ei.value)
    # the error must name both directions of the mismatch
    assert "extra_moment" in msg and "b" in msg


def test_bf16_dtype_survives_numpy_npz(tmp_ckpt_dir):
    """The exact regression the dtype record exists for: numpy round-trips
    bfloat16 as a raw void array; restore must reinterpret it."""
    w = jnp.asarray(np.arange(6).reshape(2, 3), jnp.bfloat16)
    save_checkpoint(tmp_ckpt_dir, 0, {"w": w})
    restored, _ = restore_checkpoint(tmp_ckpt_dir, {"w": jnp.zeros((2, 3),
                                                                   jnp.bfloat16)})
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(w, np.float32))


def test_keep_prunes_all_but_newest(tmp_ckpt_dir):
    tree = {"w": jnp.zeros(2)}
    for s in range(1, 6):
        save_checkpoint(tmp_ckpt_dir, s, tree, keep=2)
    steps = sorted(int(f.split("_")[1].split(".")[0])
                   for f in os.listdir(tmp_ckpt_dir)
                   if f.startswith("step_") and f.endswith(".npz"))
    assert steps == [4, 5]
    with pytest.raises(ValueError):
        prune_checkpoints(tmp_ckpt_dir, 0)


def test_restore_selects_requested_step(tmp_ckpt_dir):
    for s in (1, 2, 3):
        save_checkpoint(tmp_ckpt_dir, s, {"w": jnp.full(2, float(s))})
    restored, step = restore_checkpoint(tmp_ckpt_dir, {"w": jnp.zeros(2)},
                                        step=2)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["w"]), [2.0, 2.0])
