"""MUST-FLAG: reading a buffer after donating it."""
import jax


def train(state, window, rounds):
    step = jax.jit(_epoch, donate_argnums=(0,))
    new_state = step(state, window, rounds)
    # flag: `state` was donated on the call above — its buffer may be
    # aliased into new_state; reading it now is use-after-donate
    drift = new_state - state
    return new_state, drift


def _epoch(state, window, rounds):
    return state + window.sum() * rounds.size
