"""MUST-PASS: the donated name is rebound by the call (the carry idiom)."""
import jax


def train(state, window, rounds):
    step = jax.jit(_epoch, donate_argnums=(0,))
    for _ in range(3):
        state = step(state, window, rounds)   # rebind: old buffer gone
    return state


def _epoch(state, window, rounds):
    return state + window.sum() * rounds.size
