"""MUST-FLAG: traced-value leaks inside jitted bodies."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def loss_with_float(w, x):
    scale = float(jnp.mean(x))          # flag: float() on a tracer
    return w * scale


def outer(xs):
    def body(carry, x):
        if bool(x > 0):                 # flag: bool() on a tracer
            carry = carry + x
        return carry, x.item()          # flag: .item() on a tracer
    return jax.lax.scan(body, 0.0, xs)


step = jax.jit(lambda w: np.asarray(w) + 1)   # flag: host transfer in jit
