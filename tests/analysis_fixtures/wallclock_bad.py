"""MUST-FLAG: wallclock / host RNG frozen into traced code."""
import random
import time

import jax
import numpy as np


@jax.jit
def stamped_step(w):
    t = time.time()                      # flag: frozen at trace time
    return w + t


@jax.jit
def noisy_step(w):
    noise = np.random.normal()           # flag: host RNG sampled once
    jitter = random.random()             # flag: host RNG sampled once
    return w + noise + jitter
