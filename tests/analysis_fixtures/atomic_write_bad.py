"""MUST-FLAG: in-place durable writes (imagine this lives in checkpoint/)."""
import json

import numpy as np


def publish_state(path, arrays, meta):
    np.savez(path, **arrays)             # flag: torn file on crash
    with open(path + ".json", "w") as f:  # flag: in-place truncate-write
        json.dump(meta, f)               # flag: dump into non-temp handle
