"""MUST-PASS: the jit is hoisted (or cached by key) outside the loop."""
import jax


def serve_waves(waves, params):
    step = jax.jit(lambda p, w: p @ w)       # one wrapper, one cache
    return [step(params, wave) for wave in waves]


def span_steps(spans):
    cache = {}
    for span in spans:
        if span not in cache:
            # lint: allow[jit-in-loop] cached by span key — compiled once per span
            cache[span] = jax.jit(lambda x, s=span: x[:s])
    return cache
