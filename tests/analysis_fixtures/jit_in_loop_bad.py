"""MUST-FLAG: a fresh jit wrapper built every loop iteration."""
import jax


def serve_waves(waves, params):
    outs = []
    for wave in waves:
        step = jax.jit(lambda p, w: p @ w)   # flag: fresh cache per wave
        outs.append(step(params, wave))
    return outs
