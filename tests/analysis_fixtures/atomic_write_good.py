"""MUST-PASS: the tmp -> fsync -> os.replace publish idiom."""
import json
import os

import numpy as np


def publish_state(path, arrays, meta):
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)

    meta_tmp = path + ".json.tmp"
    with open(meta_tmp, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(meta_tmp, path + ".json")
