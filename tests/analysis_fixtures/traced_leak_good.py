"""MUST-PASS: the same shapes of code, leak-free."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def loss(w, x):
    scale = jnp.mean(x)                  # stays traced
    return w * scale


def outer(xs):
    def body(carry, x):
        carry = jnp.where(x > 0, carry + x, carry)   # traced branch
        return carry, x
    return jax.lax.scan(body, 0.0, xs)


def host_side(w):
    # float()/np.asarray OUTSIDE any traced region are fine
    return float(np.asarray(w).mean())
