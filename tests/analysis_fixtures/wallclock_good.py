"""MUST-PASS: timing outside traces, jax.random with threaded keys inside."""
import time

import jax


@jax.jit
def noisy_step(w, key):
    return w + jax.random.normal(key, w.shape)   # keyed RNG is traced


def timed_run(w, key):
    start = time.perf_counter()          # host timing outside the trace
    out = noisy_step(w, key)
    out.block_until_ready()
    return out, time.perf_counter() - start
