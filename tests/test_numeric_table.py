"""MLNumericTable: matrixBatchMap / reduce semantics, partition invariance
(the paper's core 'batch operation on partitions' contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.local_matrix import LocalMatrix
from repro.core.numeric_table import MLNumericTable


def _table(rng, n=16, d=4, shards=4):
    return MLNumericTable.from_numpy(
        np.asarray(rng.normal(size=(n, d)), np.float32), num_shards=shards)


class TestBasics:
    def test_shapes(self, rng):
        t = _table(rng)
        assert t.num_rows == 16 and t.num_cols == 4 and t.rows_per_shard == 4

    def test_indivisible_rejected(self, rng):
        with pytest.raises(ValueError):
            MLNumericTable.from_numpy(np.zeros((10, 2), np.float32), num_shards=3)

    def test_map_rows(self, rng):
        t = _table(rng)
        doubled = t.map_rows(lambda r: r * 2)
        np.testing.assert_allclose(np.asarray(doubled.data),
                                   2 * np.asarray(t.data), rtol=1e-6)


class TestMatrixBatchMap:
    def test_identity(self, rng):
        t = _table(rng)
        out = t.matrix_batch_map(lambda m: m)
        np.testing.assert_allclose(np.asarray(out.data), np.asarray(t.data))

    def test_per_partition_rowsum(self, rng):
        """One output row per partition: the local-stats pattern every MLI
        algorithm uses before a global reduce."""
        t = _table(rng, n=16, shards=4)
        out = t.matrix_batch_map(lambda m: LocalMatrix(jnp.sum(m.data, 0)[None, :]))
        assert out.num_rows == 4
        blocks = np.asarray(t.data).reshape(4, 4, 4)
        np.testing.assert_allclose(np.asarray(out.data), blocks.sum(1), rtol=1e-5)

    def test_broadcast_args(self, rng):
        t = _table(rng)
        w = jnp.ones((4,), jnp.float32)
        out = t.matrix_batch_map(lambda m, ww: LocalMatrix(m.data @ ww[:, None]), w)
        np.testing.assert_allclose(np.asarray(out.data)[:, 0],
                                   np.asarray(t.data).sum(1), rtol=1e-5)

    def test_works_under_jit(self, rng):
        t = _table(rng)

        @jax.jit
        def f(data):
            tt = MLNumericTable(data, num_shards=4)
            return tt.matrix_batch_map(lambda m: m * 2).data

        np.testing.assert_allclose(np.asarray(f(t.data)),
                                   2 * np.asarray(t.data), rtol=1e-6)


class TestReduce:
    def test_reduce_sum_matches_numpy(self, rng):
        t = _table(rng)
        got = t.reduce(jnp.add)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(t.data).sum(0), rtol=1e-4, atol=1e-5)

    def test_reduce_max(self, rng):
        t = _table(rng)
        got = t.reduce(jnp.maximum, identity=jnp.full((4,), -np.inf, jnp.float32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(t.data).max(0), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n_shards=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 2**16))
def test_reduce_shard_invariance_property(n_shards, seed):
    """Global reduce must not depend on the partitioning — the property that
    makes MLI algorithms deterministic across cluster sizes."""
    rng = np.random.default_rng(seed)
    X = np.asarray(rng.normal(size=(16, 3)), np.float32)
    t = MLNumericTable.from_numpy(X, num_shards=n_shards)
    np.testing.assert_allclose(np.asarray(t.reduce(jnp.add)), X.sum(0),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(shards=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**16))
def test_batchmap_then_concat_property(shards, seed):
    """matrixBatchMap with a row-preserving fn == applying fn globally."""
    rng = np.random.default_rng(seed)
    X = np.asarray(rng.normal(size=(8, 3)), np.float32)
    t = MLNumericTable.from_numpy(X, num_shards=shards)
    out = t.matrix_batch_map(lambda m: LocalMatrix(m.data * 3 + 1))
    np.testing.assert_allclose(np.asarray(out.data), X * 3 + 1, rtol=1e-6)
