"""Kill-and-resume equivalence on a real 8-device mesh.

Three subprocesses per scenario:

  1. **straight** — stream-train logreg, MinibatchSGD, and k-means for E
     epochs under all three collective schedules; print final models.
  2. **killed** — same runs with `CheckpointPolicy(every_epochs=1)`, but
     each stopped at E/2 — and the process is genuinely SIGKILLed
     mid-training-loop (an uncatchable preemption, delivered when the
     stream is asked for the next window), leaving only the on-disk
     snapshots behind.
  3. **resumed** — fresh process, `resume()` from each checkpoint dir
     (littered with `.tmp` partials and foreign files first), continue to
     E epochs; print final models and stream positions.

The resumed models must match the uninterrupted ones to fp tolerance
(they are bit-for-bit on the same mesh: same compiled program, same
state), and every stream must land exactly at step E.
"""
import signal

import numpy as np
import pytest

from conftest import result_json, run_devices_subprocess

pytestmark = pytest.mark.skipif(not hasattr(signal, "SIGKILL"),
                                reason="POSIX-only kill semantics")

E, HALF = 4, 2

_COMMON = """
import json, os, signal
import numpy as np
import jax, jax.numpy as jnp

from repro.core.compat import make_mesh
from repro.core.runner import CheckpointPolicy
from repro.core.collectives import CollectiveSchedule
from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm, LogisticRegressionParameters)
from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.optimizer import MinibatchSGD, MinibatchSGDParameters
from repro.data import BatchIterator
from repro.testing import ChaosInjector, Fault

assert len(jax.devices()) == 8, jax.devices()
mesh = make_mesh((8,), ("data",))
ROWS, D, E, HALF, CHUNKS = 128, 8, %(E)d, %(HALF)d, 2


def clf_source(step):
    rng = np.random.default_rng(1000 + step)
    w = np.linspace(-1, 1, D).astype(np.float32)
    X = rng.normal(size=(ROWS, D)).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    return {"data": np.concatenate([y[:, None], X], 1).astype(np.float32)}


def reg_source(step):
    rng = np.random.default_rng(2000 + step)
    w = np.arange(1, D + 1, dtype=np.float32) / D
    X = rng.normal(size=(ROWS, D)).astype(np.float32)
    return {"data": np.concatenate([(X @ w)[:, None], X], 1)}


def km_source(step):
    rng = np.random.default_rng(3000 + step)
    centers = np.stack([np.full(D, -2.0), np.zeros(D), np.full(D, 2.0),
                        np.linspace(-3, 3, D)]).astype(np.float32)
    idx = rng.integers(0, 4, size=ROWS)
    return {"data": (centers[idx]
                     + 0.3 * rng.normal(size=(ROWS, D))).astype(np.float32)}


def linreg_grad(vec, w):
    x = vec[1:]
    return x * (jnp.dot(x, w) - vec[0])


SOURCES = {"logreg": clf_source, "minibatch": reg_source, "kmeans": km_source}


def train(algo, sched, num_epochs, ckpt=None, resume=False, kill_step=None):
    source = SOURCES[algo]
    stream = BatchIterator(source, mesh=mesh)
    if kill_step is not None:
        # the shared chaos machinery (repro.testing.chaos): an uncatchable
        # SIGKILL delivered when the stream is asked for the kill_step
        # window — a deterministic stand-in for a pod preemption
        injector = ChaosInjector([Fault(host=0, round=kill_step,
                                        action="kill")])
        stream = injector.wrap_stream(stream)
    if algo == "logreg":
        p = LogisticRegressionParameters(learning_rate=0.3,
                                         local_batch_size=8, schedule=sched)
        m = LogisticRegressionAlgorithm.train_stream(
            stream, p, num_epochs=num_epochs, chunks_per_epoch=CHUNKS,
            checkpoint=ckpt, resume=resume)
        return np.asarray(m.weights), stream
    if algo == "minibatch":
        p = MinibatchSGDParameters(w_init=jnp.zeros(D), grad=linreg_grad,
                                   learning_rate=0.05, schedule=sched)
        w = MinibatchSGD(p).apply_stream(stream, num_epochs,
                                         chunks_per_epoch=CHUNKS,
                                         checkpoint=ckpt, resume=resume)
        return np.asarray(w), stream
    p = KMeansParameters(k=4, seed=0, schedule=sched)
    m = KMeans.train_stream(stream, p, num_epochs=num_epochs,
                            chunks_per_epoch=CHUNKS, checkpoint=ckpt,
                            resume=resume)
    return np.asarray(m.centroids), stream


COMBOS = [(a, s) for a in ("logreg", "minibatch", "kmeans")
          for s in CollectiveSchedule]
""" % {"E": E, "HALF": HALF}

_PROG_STRAIGHT = _COMMON + """
out = {}
for algo, sched in COMBOS:
    w, _ = train(algo, sched, E)
    out[algo + "/" + sched.value] = w.tolist()
print("RESULT::" + json.dumps(out))
"""

_PROG_KILLED = _COMMON + """
base = os.environ["CKPT_BASE"]
for i, (algo, sched) in enumerate(COMBOS):
    ck = CheckpointPolicy(os.path.join(base, algo + "-" + sched.value),
                          every_epochs=1)
    if i < len(COMBOS) - 1:
        # preempted later (process-wide); each run leaves snapshots 1..HALF
        train(algo, sched, HALF, ckpt=ck)
    else:
        # the preemption itself: SIGKILL when the stream is asked for the
        # window of epoch HALF — the snapshot at HALF is already on disk
        train(algo, sched, E, ckpt=ck, kill_step=HALF)
raise SystemExit("unreachable: the SIGKILL above must fire")
"""

_PROG_RESUME = _COMMON + """
base = os.environ["CKPT_BASE"]
out = {"weights": {}, "stream_steps": {}, "latest": {}}
from repro.checkpoint import latest_step
for algo, sched in COMBOS:
    d = os.path.join(base, algo + "-" + sched.value)
    # debris a real preemption could leave: a dead partial write and an
    # operator's stray file — resume must see through both
    open(os.path.join(d, "step_99.npz.tmp"), "wb").close()
    with open(os.path.join(d, "notes.txt"), "w") as f:
        f.write("preempted here")
    ck = CheckpointPolicy(d, every_epochs=1)
    w, stream = train(algo, sched, E, ckpt=ck, resume=True)
    key = algo + "/" + sched.value
    out["weights"][key] = w.tolist()
    out["stream_steps"][key] = stream.step
    out["latest"][key] = latest_step(d)
print("RESULT::" + json.dumps(out))
"""


def test_kill_and_resume_matches_uninterrupted_run(tmp_path):
    """3 algorithms x 3 schedules: a run SIGKILLed at E/2 and resumed from
    its checkpoints must produce the same model as the uninterrupted run."""
    straight = result_json(run_devices_subprocess(_PROG_STRAIGHT))

    killed = run_devices_subprocess(_PROG_KILLED, check=False,
                                    env={"CKPT_BASE": str(tmp_path)})
    assert killed.returncode == -signal.SIGKILL, (
        f"expected SIGKILL death, got rc={killed.returncode}\n"
        f"{killed.stderr[-2000:]}")

    resumed = result_json(run_devices_subprocess(
        _PROG_RESUME, env={"CKPT_BASE": str(tmp_path)}))

    assert set(resumed["weights"]) == set(straight)
    for key, want in straight.items():
        np.testing.assert_allclose(
            np.asarray(resumed["weights"][key]), np.asarray(want),
            rtol=0, atol=1e-6,
            err_msg=f"{key}: resumed model diverged from uninterrupted run")
        # the stream was fast-forwarded to exactly the checkpointed position
        # and then consumed the remaining epochs
        assert resumed["stream_steps"][key] == E, key
        # resume continued checkpointing to the same dir
        assert resumed["latest"][key] == E, key


def test_fit_cli_checkpoints_and_resumes(tmp_path):
    """The launcher surface: a run that checkpoints, then a --resume
    relaunch that continues from the snapshot instead of restarting."""
    common = ("--algorithm kmeans --rows-per-epoch 32 --features 4 "
              "--chunks-per-epoch 2 --num-shards 2 "
              f"--ckpt-dir {tmp_path / 'ck'}")
    prog = ("import repro.launch.fit as fit\n"
            "fit.main({args!r}.split())\n")
    first = run_devices_subprocess(
        prog.format(args=f"{common} --epochs 2"), devices=1)
    assert "starting fresh" not in first.stdout
    second = run_devices_subprocess(
        prog.format(args=f"{common} --epochs 4 --resume"), devices=1)
    assert "resuming from step 2" in second.stdout
    assert "stream position: step 4" in second.stdout
