"""Optimizer layer (paper §III-C, Fig. A4): local SGD + averaging, GD,
minibatch SGD, collective schedules, pytree optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.collectives import CollectiveSchedule
from repro.core.numeric_table import MLNumericTable
from repro.core.optimizer import (GradientDescent, GradientDescentParameters,
                                  MinibatchSGD, MinibatchSGDParameters,
                                  StochasticGradientDescent,
                                  StochasticGradientDescentParameters,
                                  soft_threshold)
from repro.data import synth_classification
from repro.optim.optimizers import adamw, lion, sgd_momentum


def _logreg_grad(vec: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Paper Fig. A4 gradient closure: vec = [label | features]."""
    y, x = vec[0], vec[1:]
    return x * (jax.nn.sigmoid(x @ w) - y)


def _dataset(n=256, d=8, shards=4, seed=0):
    X, y, _ = synth_classification(n, d, seed=seed)
    data = np.concatenate([y[:, None], X], axis=1).astype(np.float32)
    return MLNumericTable.from_numpy(data, num_shards=shards), X, y


def _accuracy(w, X, y):
    return float((((X @ np.asarray(w)) > 0) == y).mean())


class TestSGD:
    def test_converges(self):
        table, X, y = _dataset()
        p = StochasticGradientDescentParameters(
            w_init=jnp.zeros(8), grad=_logreg_grad, learning_rate=0.5, max_iter=20)
        w = StochasticGradientDescent(p).apply(table)
        assert _accuracy(w, X, y) > 0.87

    def test_all_schedules_agree(self):
        """The three wire schedules are algebraically identical (mean)."""
        table, _, _ = _dataset()
        ws = {}
        for sched in CollectiveSchedule:
            p = StochasticGradientDescentParameters(
                w_init=jnp.zeros(8), grad=_logreg_grad, learning_rate=0.5,
                max_iter=3, schedule=sched)
            ws[sched] = np.asarray(StochasticGradientDescent(p).apply(table))
        ref = ws[CollectiveSchedule.ALLREDUCE]
        for sched, w in ws.items():
            np.testing.assert_allclose(w, ref, rtol=1e-5, atol=1e-6)

    def test_local_batch_size_vectorization(self):
        """bs>1 is a different algorithm (averaged chunks) but must converge."""
        table, X, y = _dataset()
        p = StochasticGradientDescentParameters(
            w_init=jnp.zeros(8), grad=_logreg_grad, learning_rate=0.5,
            max_iter=20, local_batch_size=16)
        w = StochasticGradientDescent(p).apply(table)
        assert _accuracy(w, X, y) > 0.87

    def test_l1_prox_sparsifies(self):
        table, X, y = _dataset()
        p = StochasticGradientDescentParameters(
            w_init=jnp.zeros(8), grad=_logreg_grad, learning_rate=0.5,
            max_iter=10, prox=soft_threshold(0.05))
        w = np.asarray(StochasticGradientDescent(p).apply(table))
        p0 = StochasticGradientDescentParameters(
            w_init=jnp.zeros(8), grad=_logreg_grad, learning_rate=0.5, max_iter=10)
        w0 = np.asarray(StochasticGradientDescent(p0).apply(table))
        assert np.abs(w).sum() < np.abs(w0).sum()


class TestGD:
    def test_full_batch_gd_matches_manual(self):
        """GradientDescent == the MATLAB reference loop (Fig. A4 top)."""
        table, X, y = _dataset(n=64, d=4, shards=2, seed=1)
        p = GradientDescentParameters(
            w_init=jnp.zeros(4), grad=_logreg_grad, learning_rate=0.01, max_iter=5)
        w = np.asarray(GradientDescent(p).apply(table))

        # the paper's MATLAB reference (Fig. A4 top): summed gradient
        wm = np.zeros(4)
        sig = lambda z: 1 / (1 + np.exp(-z))
        for _ in range(5):
            g = X.T @ (sig(X @ wm) - y)
            wm = wm - 0.01 * g
        np.testing.assert_allclose(w, wm, rtol=1e-3, atol=1e-4)


class TestMinibatchSGD:
    def test_converges(self):
        table, X, y = _dataset()
        p = MinibatchSGDParameters(
            w_init=jnp.zeros(8), grad=_logreg_grad, learning_rate=0.5,
            max_iter=40, batch_per_shard=16)
        w = MinibatchSGD(p).apply(table)
        assert _accuracy(w, X, y) > 0.87


class TestPytreeOptimizers:
    @pytest.mark.parametrize("opt", [adamw(lr=0.05, warmup=0, weight_decay=0.0),
                                     sgd_momentum(lr=0.05),
                                     lion(lr=0.05, weight_decay=0.0)])
    def test_minimizes_quadratic(self, opt):
        params = {"w": jnp.ones((4,)) * 5.0}
        state = opt.init(params)
        step = jnp.zeros((), jnp.int32)
        for i in range(200):
            grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
            params, state = opt.update(grads, state, params, step + i)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_adamw_moments_fp32(self):
        opt = adamw()
        params = {"w": jnp.ones((2,), jnp.bfloat16)}
        state = opt.init(params)
        assert state["m"]["w"].dtype == jnp.float32
        assert state["v"]["w"].dtype == jnp.float32


@settings(max_examples=20, deadline=None)
@given(lam=st.floats(0.01, 1.0), seed=st.integers(0, 2**16))
def test_soft_threshold_properties(lam, seed):
    """prox_{λ||·||₁}: shrinks toward zero, exact zero inside the threshold,
    never flips sign."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=16), jnp.float32)
    out = np.asarray(soft_threshold(lam)(w, jnp.asarray(1.0)))
    w = np.asarray(w)
    assert (np.abs(out) <= np.abs(w) + 1e-7).all()
    assert (out[np.abs(w) <= lam] == 0).all()
    assert (out * w >= 0).all()
