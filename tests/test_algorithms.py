"""The paper's algorithms (§IV): logistic regression (SGD + averaging),
linear models by swapping the gradient (§IV claim), ALS, KMeans pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms.als import (ALSParameters, BroadcastALS,
                                       pack_csr_table)
from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.algorithms.linear_models import (LinearRegressionAlgorithm,
                                                 LinearRegressionParameters,
                                                 LinearSVMAlgorithm,
                                                 LinearSVMParameters)
from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm, LogisticRegressionParameters)
from repro.core.mltable import MLTable
from repro.core.numeric_table import MLNumericTable
from repro.data import (synth_classification, synth_netflix_tiled,
                        synth_text_corpus)
from repro.features.text import n_grams, tf_idf


def _cls_table(n=256, d=8, shards=4, seed=0):
    X, y, _ = synth_classification(n, d, seed=seed)
    data = np.concatenate([y[:, None], X], axis=1).astype(np.float32)
    return MLNumericTable.from_numpy(data, num_shards=shards), X, y


class TestLogisticRegression:
    def test_train_and_predict(self):
        table, X, y = _cls_table()
        model = LogisticRegressionAlgorithm.train(
            table, LogisticRegressionParameters(learning_rate=0.5, max_iter=20))
        acc = float((np.asarray(model.predict(jnp.asarray(X))).ravel() == y).mean())
        assert acc > 0.87

    def test_shard_count_stability(self):
        """More partitions (more 'machines') must not change the algorithm's
        learnability — the paper's scaling premise."""
        for shards in (1, 2, 8):
            table, X, y = _cls_table(shards=shards)
            model = LogisticRegressionAlgorithm.train(
                table, LogisticRegressionParameters(learning_rate=0.5, max_iter=20))
            acc = float((np.asarray(model.predict(jnp.asarray(X))).ravel() == y).mean())
            assert acc > 0.82, f"shards={shards}: acc={acc}"

    def test_solver_gd(self):
        table, X, y = _cls_table()
        model = LogisticRegressionAlgorithm.train(
            table, LogisticRegressionParameters(learning_rate=0.005,
                                                max_iter=30, solver="gd"))
        acc = float((np.asarray(model.predict(jnp.asarray(X))).ravel() == y).mean())
        assert acc > 0.87


class TestLinearModels:
    """'simply by changing the expression of the gradient function' (§IV)."""

    def test_linear_regression(self):
        rng = np.random.default_rng(0)
        X = np.asarray(rng.normal(size=(256, 6)), np.float32)
        w_true = np.asarray(rng.normal(size=6), np.float32)
        y = X @ w_true + 0.01 * rng.normal(size=256).astype(np.float32)
        table = MLNumericTable.from_numpy(
            np.concatenate([y[:, None], X], 1), num_shards=4)
        model = LinearRegressionAlgorithm.train(
            table, LinearRegressionParameters(learning_rate=0.1, max_iter=50))
        np.testing.assert_allclose(np.asarray(model.weights).ravel(), w_true,
                                   rtol=0.15, atol=0.05)

    def test_svm_hinge(self):
        X, y01, _ = synth_classification(256, 8, seed=0)
        y_pm = (2 * y01 - 1).astype(np.float32)        # SVM labels in {-1,+1}
        table = MLNumericTable.from_numpy(
            np.concatenate([y_pm[:, None], X], axis=1), num_shards=4)
        model = LinearSVMAlgorithm.train(
            table, LinearSVMParameters(learning_rate=0.1, max_iter=30))
        acc = float((np.asarray(model.predict(jnp.asarray(X))).ravel() == y_pm).mean())
        assert acc > 0.85

    def test_l2_regularization_shrinks(self):
        table, X, y = _cls_table()
        w_plain = LogisticRegressionAlgorithm.train(
            table, LogisticRegressionParameters(max_iter=15)).weights
        w_l2 = LogisticRegressionAlgorithm.train(
            table, LogisticRegressionParameters(max_iter=15, l2=1.0)).weights
        assert float(jnp.linalg.norm(w_l2)) < float(jnp.linalg.norm(w_plain))


class TestALS:
    def _tables(self, tiles=1, max_nnz=32, shards=4):
        M = synth_netflix_tiled(users=64, items=48, rank=4, tiles=tiles,
                                density=0.2)
        r, c = np.nonzero(M)
        v = M[r, c]
        m, n = M.shape
        data = pack_csr_table(r, c, v, m, max_nnz, num_shards=shards)
        data_t = pack_csr_table(c, r, v, n, max_nnz, num_shards=shards)
        return data, data_t, (r, c, v)

    def test_rmse_decreases(self):
        data, data_t, (r, c, v) = self._tables()
        p = ALSParameters(rank=4, lam=0.05, max_iter=1)
        m1 = BroadcastALS.train(data, p, data_transposed=data_t)
        p10 = ALSParameters(rank=4, lam=0.05, max_iter=10)
        m10 = BroadcastALS.train(data, p10, data_transposed=data_t)
        rmse1 = float(m1.rmse(r, c, v))
        rmse10 = float(m10.rmse(r, c, v))
        assert rmse10 < rmse1
        assert rmse10 < 0.5, f"rmse after 10 iters: {rmse10}"

    def test_paper_hyperparams_run(self):
        """Paper §IV-B fixes rank=10, lambda=.01, 10 iterations."""
        data, data_t, (r, c, v) = self._tables()
        p = ALSParameters(rank=10, lam=0.01, max_iter=10)
        model = BroadcastALS.train(data, p, data_transposed=data_t)
        assert float(model.rmse(r, c, v)) < 0.5

    def test_requires_transpose(self):
        data, data_t, _ = self._tables()
        with pytest.raises(ValueError):
            BroadcastALS.train(data, ALSParameters())


class TestKMeansPipeline:
    """Paper Fig. A2: textFile -> nGrams -> tfIdf -> KMeans."""

    def test_end_to_end(self):
        docs = synth_text_corpus(n_docs=32)
        table = MLTable.from_text(docs, num_partitions=4)
        feats = tf_idf(n_grams(table, n=2, top=64))
        nt = feats.to_numeric(num_shards=4)
        model = KMeans.train(nt, KMeansParameters(k=4, max_iter=10))
        labels = np.asarray(model.predict(nt.data))
        assert labels.shape[0] == 32
        assert len(np.unique(labels)) > 1          # found some structure
        inertia = float(model.inertia(nt.data))
        assert np.isfinite(inertia) and inertia >= 0
