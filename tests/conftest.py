"""Shared fixtures and helpers for the test suite.

Multi-device behavior is tested through subprocesses because the emulated
host-device count (``--xla_force_host_platform_device_count``) must be set
before jax initializes and cannot change inside one process.  The helpers
here own that boilerplate so test modules only supply the program text:

  * :func:`run_devices_subprocess` — run a ``python -c`` program with N
    emulated devices and the repo on PYTHONPATH; returns the completed
    process (``check=False`` for tests that expect a non-zero exit, e.g.
    the SIGKILL in the kill-and-resume test).
  * :func:`result_json` — parse the ``RESULT::{json}`` line a program
    prints as its structured verdict.
  * ``eight_device_run`` — fixture composing the two for the common case.
"""
import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def describe_failure(out) -> str:
    """Human-readable failure report for a subprocess: the exit status
    (naming the killing signal where applicable) plus the stderr AND
    stdout tails — a child that dies printing its error to stdout, or is
    killed by a signal with empty stderr, must not surface as a bare
    returncode."""
    rc = out.returncode
    status = f"exit code {rc}"
    if rc < 0:
        try:
            status += f" (killed by {signal.Signals(-rc).name})"
        except ValueError:
            status += " (killed by signal)"
    parts = [f"subprocess failed with {status}"]
    for name, text in (("stderr", out.stderr), ("stdout", out.stdout)):
        tail = (text or "").strip()[-2000:]
        parts.append(f"--- {name} (tail) ---\n{tail if tail else '<empty>'}")
    return "\n".join(parts)


def run_devices_subprocess(program: str, devices: int = 8, timeout: int = 540,
                           env: dict = None, check: bool = True):
    """Run ``program`` via ``python -c`` with ``devices`` emulated host
    devices.  Asserts a clean exit unless ``check=False``, with the
    child's stderr/stdout tails in the assertion message."""
    full_env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
    )
    if env:
        full_env.update(env)
    out = subprocess.run([sys.executable, "-c", program], capture_output=True,
                         text=True, env=full_env, timeout=timeout, cwd=REPO)
    if check:
        assert out.returncode == 0, describe_failure(out)
    return out


def result_json(out) -> dict:
    """Parse the last ``RESULT::{json}`` line of a subprocess' stdout."""
    lines = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")]
    assert lines, f"no RESULT:: line in output:\n{out.stdout[-2000:]}"
    return json.loads(lines[-1][len("RESULT::"):])


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tmp_ckpt_dir(tmp_path):
    """An empty checkpoint directory, cleaned up with the test."""
    d = tmp_path / "ckpt"
    d.mkdir()
    return str(d)


@pytest.fixture(scope="session")
def eight_device_run():
    """Run a program on an 8-device emulated mesh and return its parsed
    ``RESULT::`` JSON."""

    def run(program: str, timeout: int = 540, env: dict = None) -> dict:
        return result_json(run_devices_subprocess(program, devices=8,
                                                  timeout=timeout, env=env))

    return run
