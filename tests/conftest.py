"""Shared fixtures and helpers for the test suite.

Multi-device behavior is tested through subprocesses because the emulated
host-device count (``--xla_force_host_platform_device_count``) must be set
before jax initializes and cannot change inside one process.  The helpers
here own that boilerplate so test modules only supply the program text:

  * :func:`run_devices_subprocess` — run a ``python -c`` program with N
    emulated devices and the repo on PYTHONPATH; returns the completed
    process (``check=False`` for tests that expect a non-zero exit, e.g.
    the SIGKILL in the kill-and-resume test).
  * :func:`result_json` — parse the ``RESULT::{json}`` line a program
    prints as its structured verdict.
  * ``eight_device_run`` — fixture composing the two for the common case.
"""
import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# tests/ is a flat (non-package) directory: pytest only puts each test
# file's *own* directory on sys.path, so subdirectory suites (tests/chaos/)
# could not import the shared helpers (_hypothesis_compat) without this.
# A nested conftest.py would collide with this one on the module name.
if os.path.dirname(os.path.abspath(__file__)) not in sys.path:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def describe_failure(out) -> str:
    """Human-readable failure report for a subprocess: the exit status
    (naming the killing signal where applicable) plus the stderr AND
    stdout tails — a child that dies printing its error to stdout, or is
    killed by a signal with empty stderr, must not surface as a bare
    returncode."""
    rc = out.returncode
    status = f"exit code {rc}"
    if rc < 0:
        try:
            status += f" (killed by {signal.Signals(-rc).name})"
        except ValueError:
            status += " (killed by signal)"
    parts = [f"subprocess failed with {status}"]
    for name, text in (("stderr", out.stderr), ("stdout", out.stdout)):
        tail = (text or "").strip()[-2000:]
        parts.append(f"--- {name} (tail) ---\n{tail if tail else '<empty>'}")
    return "\n".join(parts)


def run_devices_subprocess(program: str, devices: int = 8, timeout: int = 540,
                           env: dict = None, check: bool = True):
    """Run ``program`` via ``python -c`` with ``devices`` emulated host
    devices.  Asserts a clean exit unless ``check=False``, with the
    child's stderr/stdout tails in the assertion message."""
    full_env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
    )
    if env:
        full_env.update(env)
    out = subprocess.run([sys.executable, "-c", program], capture_output=True,
                         text=True, env=full_env, timeout=timeout, cwd=REPO)
    if check:
        assert out.returncode == 0, describe_failure(out)
    return out


def result_json(out) -> dict:
    """Parse the last ``RESULT::{json}`` line of a subprocess' stdout."""
    lines = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")]
    assert lines, f"no RESULT:: line in output:\n{out.stdout[-2000:]}"
    return json.loads(lines[-1][len("RESULT::"):])


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tmp_ckpt_dir(tmp_path):
    """An empty checkpoint directory, cleaned up with the test."""
    d = tmp_path / "ckpt"
    d.mkdir()
    return str(d)


@pytest.fixture(scope="session")
def eight_device_run():
    """Run a program on an 8-device emulated mesh and return its parsed
    ``RESULT::`` JSON."""

    def run(program: str, timeout: int = 540, env: dict = None) -> dict:
        return result_json(run_devices_subprocess(program, devices=8,
                                                  timeout=timeout, env=env))

    return run


# --------------------------------------------------------------------------- #
# multi-host chaos harness
# --------------------------------------------------------------------------- #
class HostRun:
    """One finished host: its rank plus the CompletedProcess-ish facts."""

    def __init__(self, host_id: int, returncode: int, stdout: str,
                 stderr: str):
        self.host_id = host_id
        self.returncode = returncode
        self.stdout = stdout
        self.stderr = stderr

    @property
    def killed(self) -> bool:
        return self.returncode == -signal.SIGKILL

    @property
    def dropped(self) -> bool:
        from repro.testing.chaos import DROP_EXIT_CODE

        return self.returncode == DROP_EXIT_CODE

    def result(self) -> dict:
        return result_json(self)


@pytest.fixture(scope="session")
def chaos_hosts():
    """Fault-injecting multi-host launcher — the chaos harness.

    ``chaos_hosts(program, hosts=N, ...)`` runs ``program`` (``python -c``
    text following the ``REPRO_*`` contract of :mod:`repro.core.hostmesh`)
    as N simultaneous host subprocesses and returns one :class:`HostRun`
    per host.  Faults (:class:`repro.testing.chaos.Fault`) travel through
    the ``REPRO_CHAOS`` environment variable and fire *inside* the
    targeted host at a deterministic stream round — kill (SIGKILL), delay
    (straggler), or drop (graceful departure).

    ``global_mesh=True`` hands the gang a shared coordinator (one
    ``jax.distributed`` BSP mesh); ``False`` launches independent hosts
    (the SSP exchange lane).  ``check=False`` skips the all-exits-clean
    assertion for scenarios that *expect* a death.
    """
    from repro.core.hostmesh import free_port
    from repro.testing.chaos import faults_to_env

    def run(program: str, hosts: int = 2, devices_per_host: int = 2, *,
            faults=(), global_mesh: bool = True, env: dict = None,
            timeout: int = 540, check: bool = True):
        coordinator = f"127.0.0.1:{free_port()}"
        procs = []
        for h in range(hosts):
            host_env = dict(
                os.environ,
                PYTHONPATH=os.path.join(REPO, "src"),
                XLA_FLAGS=(f"--xla_force_host_platform_device_count="
                           f"{devices_per_host}"),
                REPRO_NUM_HOSTS=str(hosts),
                REPRO_HOST_ID=str(h),
            )
            if global_mesh:
                host_env["REPRO_COORDINATOR"] = coordinator
            else:
                host_env.pop("REPRO_COORDINATOR", None)
            if faults:
                host_env.update(faults_to_env(faults))
            if env:
                host_env.update(env)
            procs.append(subprocess.Popen(
                [sys.executable, "-c", program], env=host_env, cwd=REPO,
                stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
        runs = []
        try:
            for h, p in enumerate(procs):
                stdout, stderr = p.communicate(timeout=timeout)
                runs.append(HostRun(h, p.returncode, stdout, stderr))
        finally:
            for p in procs:
                if p.poll() is None:
                    p.kill()
        if check:
            for r in runs:
                assert r.returncode == 0, \
                    f"host {r.host_id}: {describe_failure(r)}"
        return runs

    return run
