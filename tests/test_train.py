"""Training substrate: loss decreases on learnable data, checkpoint
roundtrip, remat equivalence, microbatching/data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke
from repro.data import BatchIterator, SyntheticLMDataset
from repro.models.config import ArchConfig, BlockKind
from repro.models.transformer import TransformerLM, init_model
from repro.optim.optimizers import adamw
from repro.train.loss import cross_entropy_loss
from repro.train.step import init_train_state, make_train_step

TINY = ArchConfig(name="tiny", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64, remat=False,
                  dtype="float32", q_chunk=64)


def test_loss_decreases_on_learnable_data():
    """Train ~60 steps on a planted bigram stream; loss must drop well below
    the uniform baseline log(V)."""
    opt = adamw(lr=3e-3, warmup=10, total_steps=60, weight_decay=0.0)
    state, _ = init_train_state(jax.random.PRNGKey(0), TINY, opt)
    step_fn = make_train_step(TINY, opt)
    ds = SyntheticLMDataset(vocab_size=64, seq_len=64, batch_size=8, noise=0.0)
    losses = []
    for i, batch in zip(range(60), BatchIterator(ds.batch)):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])
    assert losses[-1] < np.log(64)


def test_cross_entropy_matches_manual():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 5, 7)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 7, size=(2, 5)), jnp.int32)
    got = float(cross_entropy_loss(logits, labels))
    logp = np.asarray(jax.nn.log_softmax(logits, -1))
    expect = -np.mean([logp[b, t, labels[b, t]] for b in range(2) for t in range(5)])
    assert abs(got - expect) < 1e-5


def test_remat_equivalence():
    """jax.checkpoint must not change the math: same grads with/without."""
    import dataclasses
    cfg_no = TINY
    cfg_re = dataclasses.replace(TINY, remat=True)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    outs = []
    for cfg in (cfg_no, cfg_re):
        state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
        step_fn = make_train_step(cfg)
        _, m = step_fn(state, batch)
        outs.append((float(m["loss"]), float(m["grad_norm"])))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5)


def test_checkpoint_roundtrip_trainstate():
    state, _ = init_train_state(jax.random.PRNGKey(0), TINY)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, state)
        blank, _ = init_train_state(jax.random.PRNGKey(1), TINY)
        restored, step = restore_checkpoint(d, blank)
        assert step == 3 and latest_step(d) == 3
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(restored.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_continues_training():
    """Save mid-run, restore into a fresh process-state, keep training —
    the restart-based fault-tolerance story (DESIGN.md §2)."""
    opt = adamw(lr=1e-3, warmup=0, total_steps=20, weight_decay=0.0)
    ds = SyntheticLMDataset(vocab_size=64, seq_len=32, batch_size=4, noise=0.0)
    step_fn = make_train_step(TINY, opt)
    state, _ = init_train_state(jax.random.PRNGKey(0), TINY, opt)
    for i in range(3):
        state, _ = step_fn(state, ds.batch(i))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, state)
        fresh, _ = init_train_state(jax.random.PRNGKey(9), TINY, opt)
        resumed, step = restore_checkpoint(d, fresh)
        assert step == 3
        out_a, _ = step_fn(state, ds.batch(3))
        out_b, _ = step_fn(resumed, ds.batch(3))
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(out_a.params)[0], np.float32),
            np.asarray(jax.tree.leaves(out_b.params)[0], np.float32), rtol=1e-6)


def test_moe_router_aux_loss_nonzero():
    cfg = get_smoke("mixtral-8x22b")
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    step_fn = make_train_step(cfg)
    batch = {"tokens": jnp.ones((2, 64), jnp.int32),
             "labels": jnp.ones((2, 64), jnp.int32)}
    _, m = step_fn(state, batch)
    assert float(m["aux"]) > 0.0      # load-balance loss is live


def test_grad_accum_equivalence():
    """grad_accum=k must give the same update as the full batch (mean of
    equal-sized microbatch means == full-batch mean)."""
    opt = adamw(lr=1e-3, warmup=0)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, 64, (8, 32)), jnp.int32)}
    batch["labels"] = batch["tokens"].copy()
    outs = {}
    for k in (1, 2, 4):
        state, _ = init_train_state(jax.random.PRNGKey(0), TINY, opt)
        step = make_train_step(TINY, opt, grad_accum=k)
        s2, m = step(state, batch)
        outs[k] = (float(m["loss"]),
                   np.asarray(jax.tree.leaves(s2.params)[0], np.float32))
    for k in (2, 4):
        assert abs(outs[k][0] - outs[1][0]) < 1e-5
        np.testing.assert_allclose(outs[k][1], outs[1][1], rtol=1e-4, atol=1e-6)


def test_grad_accum_rejects_indivisible():
    step = make_train_step(TINY, adamw(), grad_accum=3)
    state, _ = init_train_state(jax.random.PRNGKey(0), TINY)
    batch = {"tokens": jnp.ones((8, 32), jnp.int32),
             "labels": jnp.ones((8, 32), jnp.int32)}
    import pytest as _pytest
    with _pytest.raises(ValueError):
        step(state, batch)
