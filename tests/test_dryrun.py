"""End-to-end dry-run test: one cheap (arch × shape) pair per step kind runs
lower+compile on the production 16×16 mesh in a fresh 512-device subprocess
(the full 35×2 matrix is the sweep in EXPERIMENTS.md §Dry-run)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_pair(arch: str, shape: str, multi_pod: bool = False) -> dict:
    code = f"""
from repro.launch.dryrun import run_pair
import json
res = run_pair({arch!r}, {shape!r}, multi_pod={multi_pod})
print("RESULT::" + json.dumps({{k: res[k] for k in
    ('flops_per_device', 'bytes_per_device', 'collective_s', 'bottleneck',
     'chips', 'mesh')}}))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)   # dryrun module sets it itself
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=540, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
def test_train_pair_single_pod():
    res = _run_pair("qwen2-1.5b", "train_4k")
    assert res["chips"] == 256 and res["mesh"] == "16x16"
    assert res["flops_per_device"] > 0
    assert res["bottleneck"] in ("compute_s", "memory_s", "collective_s")


@pytest.mark.slow
def test_decode_pair_multi_pod():
    res = _run_pair("mamba2-2.7b", "decode_32k", multi_pod=True)
    assert res["chips"] == 512 and res["mesh"] == "2x16x16"
    assert res["bytes_per_device"] > 0


def test_planned_pairs_matrix():
    """35 baseline pairs: 10 archs × 4 shapes − 5 full-attention long_500k
    skips (granite, llava, qwen1.5, qwen2, whisper)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    code = """
from repro.launch.dryrun import planned_pairs
pairs = planned_pairs()
print(len(pairs))
skipped = {('granite-3-8b', 'long_500k'), ('llava-next-34b', 'long_500k'),
           ('qwen1.5-32b', 'long_500k'), ('qwen2-1.5b', 'long_500k'),
           ('whisper-small', 'long_500k')}
assert not (skipped & set(pairs))
for arch in ('mamba2-2.7b', 'recurrentgemma-9b', 'gemma3-1b',
             'mixtral-8x22b', 'llama4-scout-17b-16e'):
    assert (arch, 'long_500k') in pairs, arch
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=120, cwd=REPO)
    assert out.returncode == 0, out.stderr[-1500:]
    assert out.stdout.strip().splitlines()[-1] == "35"
