"""The unified Pipeline contract: one fitted object from raw table to
serving — fp-parity with the hand-composed chain, nested-stage model
search, atomic mid-stream checkpoint/resume, raw-text serving through the
microbatcher, and the full acceptance scenario on a real 8-device mesh."""
import numpy as np
import pytest

from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm,
    LogisticRegressionParameters,
)
from repro.core.mltable import MLTable
from repro.core.runner import CheckpointPolicy
from repro.data import synth_labeled_text
from repro.features import NGrams, Standardizer, TfIdf
from repro.pipeline import Pipeline
from repro.serve import ModelPredictor, PredictRequest


def _raw_table(n=64, seed=0):
    rows = synth_labeled_text(n_docs=n, seed=seed)
    return rows, MLTable.from_rows(rows, names=["label", "text"],
                                   num_partitions=4)


def _make_pipe(num_shards=4, **logreg):
    cfg = dict(learning_rate=0.5, max_iter=6, local_batch_size=4)
    cfg.update(logreg)
    return Pipeline([
        NGrams(n=1, top=32, column="text"),
        TfIdf(),
        Standardizer(),
        LogisticRegressionAlgorithm(**cfg),
    ], num_shards=num_shards)


class TestPipelineFit:
    def test_matches_hand_composed_chain(self):
        rows, raw = _raw_table()
        fitted = _make_pipe().fit(raw)

        ng = NGrams(n=1, top=32, column="text").fit(raw)
        counts = ng.transform(raw).to_numeric(4)
        tf = TfIdf().fit(counts, default_skip=(0,))
        t2 = tf.transform(counts)
        sc = Standardizer().fit(t2, default_skip=(0,))
        final = sc.transform(t2)
        hand = LogisticRegressionAlgorithm(
            learning_rate=0.5, max_iter=6, local_batch_size=4).fit(final)

        np.testing.assert_array_equal(np.asarray(fitted.model.weights),
                                      np.asarray(hand.weights))

    def test_label_column_survives_featurization(self):
        rows, raw = _raw_table()
        table = _make_pipe().fit(raw).transform(raw)
        np.testing.assert_array_equal(np.asarray(table.data)[:, 0],
                                      [r[0] for r in rows])

    def test_transformer_only_pipeline(self):
        _, raw = _raw_table(32)
        pipe = Pipeline([NGrams(n=1, top=16, column="text"), TfIdf()],
                        num_shards=4, supervised=True)
        fitted = pipe.fit(raw)
        assert fitted.model is None
        out = fitted.transform(raw)
        assert out.num_rows == 32

    def test_stage_instances_required(self):
        with pytest.raises(TypeError, match="instance"):
            Pipeline([NGrams, LogisticRegressionAlgorithm()])

    def test_nested_config_split(self):
        pipe = _make_pipe()
        feat, est = pipe.split_config({"ngrams.top": 16, "tfidf.skip": None,
                                       "logreg.learning_rate": 0.1,
                                       "l2": 0.01})
        assert feat == {"ngrams": {"top": 16}, "tfidf": {"skip": None}}
        assert est == {"learning_rate": 0.1, "l2": 0.01}
        with pytest.raises(KeyError, match="unknown stage"):
            pipe.split_config({"nope.x": 1})

    def test_raw_predict_matches_table_predict(self):
        rows, raw = _raw_table()
        fitted = _make_pipe().fit(raw)
        table = fitted.transform(raw)
        via_table = np.asarray(fitted.model.predict(table.data[:, 1:]))
        via_rows = np.asarray(fitted.predict([t for _, t in rows]))
        np.testing.assert_array_equal(via_table, via_rows)


class TestPipelineSearch:
    def test_nested_stage_params_and_grouping(self):
        from repro.tune import ModelSearch, grid

        _, raw = _raw_table(96)
        pipe = _make_pipe(max_iter=2, local_batch_size=1)
        configs = grid({"logreg.learning_rate": [0.1, 0.5],
                        "ngrams.top": [8, 16]})
        res = ModelSearch(algorithm=pipe, configs=configs, num_epochs=3,
                          chunks_per_epoch=2, folds=3, seed=0).run(raw)
        assert len(res.trials) == 4
        assert all(np.isfinite(t.score) for t in res.trials)
        # trials with ngrams.top=8 trained in an 8-feature space
        by_cfg = {tuple(sorted(t.config.items())): t for t in res.trials}
        t8 = by_cfg[(("logreg.learning_rate", 0.1), ("ngrams.top", 8))]
        t16 = by_cfg[(("logreg.learning_rate", 0.1), ("ngrams.top", 16))]
        assert np.asarray(t8.state).shape[0] < np.asarray(t16.state).shape[0]

    def test_search_checkpoint_resume_trial_for_trial(self, tmp_ckpt_dir):
        from repro.tune import ModelSearch, grid

        _, raw = _raw_table(96)
        pipe = _make_pipe(max_iter=2, local_batch_size=1)
        configs = grid({"logreg.learning_rate": [0.1, 0.5],
                        "ngrams.top": [8, 16]})

        def make_search(cb=None):
            return ModelSearch(algorithm=pipe, configs=configs, num_epochs=3,
                               chunks_per_epoch=2, folds=3, seed=0,
                               ckpt_dir=tmp_ckpt_dir, unit_callback=cb)

        full = make_search().run(raw)

        class Kill(Exception):
            pass

        def cb(units, idxs):
            if units == 1:
                raise Kill()

        import shutil
        shutil.rmtree(tmp_ckpt_dir)
        with pytest.raises(Kill):
            make_search(cb).run(raw)
        resumed = make_search().run(raw, resume=True)
        assert resumed.scores == full.scores
        assert resumed.best.config == full.best.config

    def test_fingerprint_refuses_different_pipeline(self, tmp_ckpt_dir):
        from repro.tune import ModelSearch, grid

        _, raw = _raw_table(96)
        configs = grid({"logreg.learning_rate": [0.1, 0.5]})
        ModelSearch(algorithm=_make_pipe(max_iter=2, local_batch_size=1),
                    configs=configs,
                    num_epochs=2, seed=0, ckpt_dir=tmp_ckpt_dir).run(raw)
        other = Pipeline([NGrams(n=2, top=32, column="text"), TfIdf(),
                          Standardizer(),
                          LogisticRegressionAlgorithm(max_iter=2)],
                         num_shards=4)
        with pytest.raises(ValueError, match="fingerprint"):
            ModelSearch(algorithm=other, configs=configs, num_epochs=2,
                        seed=0, ckpt_dir=tmp_ckpt_dir).run(raw, resume=True)


class TestPipelineStreamResume:
    def test_mid_stream_resume_bit_exact(self, tmp_ckpt_dir):
        _, raw = _raw_table()
        straight = _make_pipe().fit_stream(raw, num_epochs=6,
                                           chunks_per_epoch=2)
        _make_pipe().fit_stream(
            raw, num_epochs=3, chunks_per_epoch=2,
            checkpoint=CheckpointPolicy(tmp_ckpt_dir, every_epochs=1))
        resumed = _make_pipe().fit_stream(
            raw, num_epochs=6, chunks_per_epoch=2,
            checkpoint=CheckpointPolicy(tmp_ckpt_dir, every_epochs=1),
            resume=True)
        np.testing.assert_array_equal(np.asarray(straight.model.weights),
                                      np.asarray(resumed.model.weights))
        # the featurizers were RESTORED from the snapshot, not refit
        assert resumed["ngrams"].vocab == straight["ngrams"].vocab
        np.testing.assert_array_equal(np.asarray(straight["tfidf"].idf),
                                      np.asarray(resumed["tfidf"].idf))

    def test_snapshot_is_one_atomic_artifact(self, tmp_ckpt_dir):
        """One step file carries featurizer state + model carry + stream
        position — no side files."""
        import os

        from repro.checkpoint import load_metadata

        _, raw = _raw_table()
        _make_pipe().fit_stream(
            raw, num_epochs=2, chunks_per_epoch=2,
            checkpoint=CheckpointPolicy(tmp_ckpt_dir, every_epochs=1))
        files = sorted(os.listdir(tmp_ckpt_dir))
        assert files == ["step_1.npz", "step_2.npz"]
        meta = load_metadata(tmp_ckpt_dir)
        assert meta["wrapped"] is True
        assert meta["stream_step"] == 2
        pmeta = meta["extra"]["pipeline"]
        assert [n for n, _ in pmeta["stages"]] == \
            ["ngrams", "tfidf", "standardizer"]

    def test_resume_without_pipeline_state_refuses(self, tmp_ckpt_dir):
        """A plain (non-pipeline) snapshot cannot silently resume a
        pipeline run."""
        from repro.data import BatchIterator

        def source(step):
            g = np.random.default_rng(step)
            X = g.normal(size=(32, 4)).astype(np.float32)
            y = (X.sum(1) > 0).astype(np.float32)
            return {"data": np.concatenate([y[:, None], X], 1)}

        LogisticRegressionAlgorithm(max_iter=2).fit_stream(
            BatchIterator(source), num_epochs=2, num_shards=2,
            checkpoint=CheckpointPolicy(tmp_ckpt_dir))
        _, raw = _raw_table()
        with pytest.raises(ValueError, match="pipeline"):
            _make_pipe().fit_stream(
                raw, num_epochs=4, chunks_per_epoch=2,
                checkpoint=CheckpointPolicy(tmp_ckpt_dir), resume=True)


class TestPipelineServing:
    def test_raw_text_through_microbatcher(self):
        rows, raw = _raw_table()
        fitted = _make_pipe().fit(raw)
        offline = np.asarray(fitted.predict([t for _, t in rows]))

        service = ModelPredictor(fitted, max_batch=16)
        reqs = [service.submit(PredictRequest(features=t))
                for _, t in rows]
        service.flush()
        served = np.asarray([float(r.result[0]) for r in reqs])
        np.testing.assert_array_equal(served, offline)
        assert service.batches == 4           # 64 rows / 16 per microbatch

    def test_single_string_request(self):
        rows, raw = _raw_table(32)
        fitted = _make_pipe().fit(raw)
        service = ModelPredictor(fitted, max_batch=8)
        req = service.submit(PredictRequest(features=rows[0][1]))
        service.flush()
        assert req.done and req.result.shape == (1,)

    def test_raw_request_without_featurizer_rejected_at_submit(self, rng):
        """A raw request on a featurizer-less service fails fast at submit
        — it must never poison queued valid requests at flush time."""
        from repro.core.numeric_table import MLNumericTable

        X = np.asarray(rng.normal(size=(32, 4)), np.float32)
        y = (X.sum(1) > 0).astype(np.float32)
        t = MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                      num_shards=2)
        model = LogisticRegressionAlgorithm(max_iter=2).fit(t)
        service = ModelPredictor(model, max_batch=8)
        ok = service.submit(PredictRequest(features=X[:3, :]))
        with pytest.raises(ValueError, match="featurize"):
            service.submit(PredictRequest(features="some raw text"))
        service.flush()
        assert ok.done and ok.result.shape == (3,)

    def test_serving_with_bias_adder_stage(self):
        """A bias column generated mid-chain exists in serving rows: only
        the label columns are absent, so apply() must pass the bias
        through rather than dropping it (width-mismatch regression)."""
        from repro.features import BiasAdder

        rows, raw = _raw_table()
        pipe = Pipeline([
            NGrams(n=1, top=32, column="text"),
            TfIdf(),
            BiasAdder(),
            Standardizer(),
            LogisticRegressionAlgorithm(learning_rate=0.5, max_iter=6,
                                        local_batch_size=4),
        ], num_shards=4)
        fitted = pipe.fit(raw)
        table = fitted.transform(raw)
        via_table = np.asarray(fitted.model.predict(table.data[:, 1:]))
        via_rows = np.asarray(fitted.predict([t for _, t in rows]))
        np.testing.assert_array_equal(via_table, via_rows)
        # the bias column really passed through as 1.0
        bias_col = list(table.names).index("bias")
        np.testing.assert_array_equal(np.asarray(table.data)[:, bias_col],
                                      1.0)

    def test_corpus_containing_the_token_label_is_safe(self):
        """Generated gram columns are namespaced (``ng:…``), so a corpus
        containing the words "label"/"bias" cannot trip the auto-skip
        name matching (featurization-corruption regression)."""
        rows = [(float(i % 2),
                 ("label bias alpha beta" if i % 2 else "label gamma delta"))
                for i in range(32)]
        raw = MLTable.from_rows(rows, names=["label", "text"],
                                num_partitions=4)
        fitted = Pipeline([
            NGrams(n=1, top=16, column="text"), TfIdf(), Standardizer(),
            LogisticRegressionAlgorithm(max_iter=4),
        ], num_shards=4).fit(raw)
        table = fitted.transform(raw)
        # the real label column survives; the "label" GRAM column was
        # featurized like any other word
        np.testing.assert_array_equal(np.asarray(table.data)[:, 0],
                                      [r[0] for r in rows])
        assert "ng:label" in table.names
        via_table = np.asarray(fitted.model.predict(table.data[:, 1:]))
        via_rows = np.asarray(fitted.predict([t for _, t in rows]))
        np.testing.assert_array_equal(via_table, via_rows)


# --------------------------------------------------------------------------- #
# acceptance: the full scenario on a REAL 8-device mesh (subprocess)
# --------------------------------------------------------------------------- #
_ACCEPTANCE_PROGRAM = """
import json
import numpy as np
import jax

from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm, LogisticRegressionParameters)
from repro.core.compat import make_mesh
from repro.core.mltable import MLTable
from repro.core.runner import CheckpointPolicy
from repro.data import synth_labeled_text
from repro.features import NGrams, Standardizer, TfIdf
from repro.pipeline import Pipeline
from repro.serve import ModelPredictor, PredictRequest
from repro.tune import ModelSearch, grid

assert len(jax.devices()) == 8
mesh = make_mesh((8,), ("data",))
rows = synth_labeled_text(n_docs=128, seed=0)
raw = MLTable.from_rows(rows, names=["label", "text"], num_partitions=4)
out = {}

def make_pipe():
    return Pipeline([
        NGrams(n=1, top=32, column="text"),
        TfIdf(),
        Standardizer(),
        LogisticRegressionAlgorithm(learning_rate=0.5, max_iter=6,
                                    local_batch_size=4),
    ], mesh=mesh)

# 1. fits through DistributedRunner on the mesh, fp-identical to the
#    hand-composed function chain
fitted = make_pipe().fit(raw)
table = fitted.transform(raw)
out["meshed"] = bool(table.mesh is not None and table.num_shards == 8)

ng = NGrams(n=1, top=32, column="text").fit(raw)
counts = ng.transform(raw).to_numeric(mesh=mesh)
tf = TfIdf().fit(counts, default_skip=(0,))
sc_in = tf.transform(counts)
sc = Standardizer().fit(sc_in, default_skip=(0,))
hand = LogisticRegressionAlgorithm(
    learning_rate=0.5, max_iter=6, local_batch_size=4).fit(sc.transform(sc_in))
out["hand_chain_fp_identical"] = bool(np.array_equal(
    np.asarray(fitted.model.weights), np.asarray(hand.weights)))

# 2. tuned by ModelSearch over nested stage params
search_pipe = Pipeline([
    NGrams(n=1, top=32, column="text"),
    TfIdf(),
    Standardizer(),
    LogisticRegressionAlgorithm(learning_rate=0.5, max_iter=6),
], mesh=mesh)
res = ModelSearch(algorithm=search_pipe,
                  configs=grid({"logreg.learning_rate": [0.1, 0.5],
                                "ngrams.top": [16, 32]}),
                  num_epochs=2, chunks_per_epoch=2, folds=3, seed=0).run(raw)
out["search"] = bool(len(res.trials) == 4
                     and all(np.isfinite(t.score) for t in res.trials))

# 3. checkpoint/resumes bit-for-bit mid-stream
import tempfile
with tempfile.TemporaryDirectory() as d:
    straight = make_pipe().fit_stream(raw, num_epochs=6, chunks_per_epoch=2)
    make_pipe().fit_stream(raw, num_epochs=3, chunks_per_epoch=2,
                           checkpoint=CheckpointPolicy(d, every_epochs=1))
    resumed = make_pipe().fit_stream(raw, num_epochs=6, chunks_per_epoch=2,
                                     checkpoint=CheckpointPolicy(d, every_epochs=1),
                                     resume=True)
    out["stream_resume_bit_exact"] = bool(np.array_equal(
        np.asarray(straight.model.weights), np.asarray(resumed.model.weights)))

# 4. serves raw text through ModelPredictor, fp-identical to offline
service = ModelPredictor(fitted, max_batch=16)
reqs = [service.submit(PredictRequest(features=t)) for _, t in rows[:32]]
service.flush()
served = np.asarray([float(r.result[0]) for r in reqs])
offline = np.asarray(fitted.predict([t for _, t in rows[:32]]))
out["served_fp_identical"] = bool(np.array_equal(served, offline))

print("RESULT::" + json.dumps(out))
"""


def test_pipeline_acceptance_on_8_device_mesh(eight_device_run):
    """One Pipeline object: fits through DistributedRunner on the mesh
    (fp-identical to the hand-composed chain), is tuned over nested stage
    params, resumes bit-for-bit mid-stream, and serves raw text through
    the microbatcher."""
    flags = eight_device_run(_ACCEPTANCE_PROGRAM)
    bad = [k for k, ok in flags.items() if not ok]
    assert not bad, f"acceptance checks failed: {bad}"
