"""Serving correctness: decode-with-cache must agree with the full forward
pass (the strongest KV-cache invariant, covering ring buffers, RG-LRU and
SSD recurrent states), plus the batch engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models.transformer import TransformerLM, init_model
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(1)

# decode-vs-forward agreement holds exactly only when every attention layer
# sees the same key set in both modes; ring caches hold the full history as
# long as S + new tokens ≤ window, which the smoke windows (32) bound.
DECODE_S = 24
NEW_TOKENS = 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    import dataclasses
    cfg = get_smoke(arch)
    if cfg.vision_tokens or cfg.encoder_layers:
        pytest.skip("frontend-stub archs tested text-only in engine test")
    if cfg.num_experts:
        # capacity-factor token dropping is sequence-length dependent, so
        # forward(S+k) and prefill(S)+decode differ by design unless no
        # token is ever dropped — give every expert full capacity here.
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.num_experts) / max(cfg.top_k, 1))
    model = TransformerLM(cfg)
    params, _ = init_model(KEY, cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    size=(1, DECODE_S + NEW_TOKENS)), jnp.int32)

    # ground truth: full forward over the whole sequence
    full_logits, _ = model.forward(params, toks)

    # prefill the prompt, then decode the remaining tokens one by one
    cache = model.init_cache(1, 64)
    logits, cache = model.prefill(params, toks[:, :DECODE_S], cache)
    np.testing.assert_allclose(
        np.asarray(logits[0, -1], np.float32),
        np.asarray(full_logits[0, DECODE_S - 1], np.float32),
        rtol=2e-2, atol=2e-2)
    for i in range(NEW_TOKENS):
        pos = DECODE_S + i
        logits, cache = model.decode_step(params, toks[:, pos:pos + 1],
                                          jnp.asarray(pos), cache)
        np.testing.assert_allclose(
            np.asarray(logits[0, -1], np.float32),
            np.asarray(full_logits[0, pos], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode step {i} diverged from forward")


def test_sliding_window_cache_evicts():
    """After S >> window, a global-cache reference and the ring cache must
    agree (ring keeps exactly the last `window` keys)."""
    import dataclasses
    cfg = get_smoke("mixtral-8x22b")           # pure SWA arch, window=32
    cfg = dataclasses.replace(                 # no MoE token drops (see above)
        cfg, capacity_factor=float(cfg.num_experts) / max(cfg.top_k, 1))
    model = TransformerLM(cfg)
    params, _ = init_model(KEY, cfg)
    rng = np.random.default_rng(0)
    S = 48                                      # > window 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, S + 1)), jnp.int32)
    full_logits, _ = model.forward(params, toks)
    cache = model.init_cache(1, 64)
    _, cache = model.prefill(params, toks[:, :S], cache)
    logits, _ = model.decode_step(params, toks[:, S:S + 1], jnp.asarray(S), cache)
    np.testing.assert_allclose(np.asarray(logits[0, -1], np.float32),
                               np.asarray(full_logits[0, S], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_engine_serves_batch():
    cfg = get_smoke("qwen2-1.5b")
    params, _ = init_model(KEY, cfg)
    engine = ServeEngine(cfg, params, batch_size=3, max_seq=96)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=16).astype(np.int32),
                    max_new_tokens=6) for _ in range(3)]
    done = engine.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out_tokens) == 6 for r in done)
    # greedy decode is deterministic: same prompt -> same output
    reqs2 = [Request(prompt=reqs[0].prompt.copy(), max_new_tokens=6)]
    done2 = engine.run(reqs2)
    assert done2[0].out_tokens == done[0].out_tokens


def test_batched_group_decode_matches_sequential():
    """The continuous-batching path (all slots share one fused per-slot-
    position decode step per token) must emit exactly the sequential
    slot-at-a-time outputs.  (The full mixed-length/backfill matrix lives
    in tests/test_serve_continuous.py.)"""
    cfg = get_smoke("qwen2-1.5b")
    params, _ = init_model(KEY, cfg)
    engine = ServeEngine(cfg, params, batch_size=4, max_seq=96)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
               for _ in range(3)]
    batched = engine.run([Request(prompt=p.copy(), max_new_tokens=5)
                          for p in prompts])
    seq = [engine._run_one(Request(prompt=p.copy(), max_new_tokens=5))
           for p in prompts]
    for b, s in zip(batched, seq):
        assert b.out_tokens == s.out_tokens


def test_mixed_length_requests_served_continuously():
    cfg = get_smoke("qwen2-1.5b")
    params, _ = init_model(KEY, cfg)
    engine = ServeEngine(cfg, params, batch_size=4, max_seq=96)
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
                    max_new_tokens=4)
            for n in (8, 16, 8, 16, 24)]          # 5 mixed lengths, 4 slots
    done = engine.run(reqs)
    assert all(r.done and len(r.out_tokens) == 4 for r in done)


# --------------------------------------------------------------------------- #
# ModelPredictor flush failure semantics (regression)
# --------------------------------------------------------------------------- #
def test_flush_failure_keeps_queue():
    """A predict failure mid-flush must leave every queued request intact
    and the stats untouched — the old flush cleared the queue *before*
    running any microbatch, so a bad ``predict_fn`` (or a compile error)
    silently dropped the whole queue with ``done=False`` and no way to
    resubmit."""
    from repro.serve.predictor import ModelPredictor, PredictRequest

    calls = {"n": 0}

    def bad_predict(X):
        calls["n"] += 1
        raise RuntimeError("boom")

    svc = ModelPredictor(model=None, max_batch=4, predict_fn=bad_predict)
    reqs = [svc.submit(PredictRequest(features=np.ones((2, 3), np.float32)))
            for _ in range(3)]
    with pytest.raises(RuntimeError, match="boom"):
        svc.flush()
    # the queue survives, nothing is marked done, stats rolled back
    assert svc.queued == 3
    assert all(not r.done and r.result is None for r in reqs)
    assert svc.batches == 0 and svc.rows_padded == 0
    assert svc.report()["rows_served"] == 0

    # a retry with a working predict serves the SAME queued requests
    svc._predict = lambda X: X.sum(axis=1)
    svc._compiled = None
    done = svc.flush()
    assert [r is q for r, q in zip(done, reqs)] == [True] * 3
    assert all(r.done and r.result.shape == (2,) for r in reqs)
    assert svc.queued == 0 and svc.rows_served == 6
