"""Regressions for the SSP exchange fixes (ISSUE 7).

Two host-side bugs in :class:`repro.core.exchange.ParamStore`, both found
by the chaos harness and both reproducible without jax:

  * ``wait_clock`` used a FIXED deadline: a slow-but-alive straggler that
    kept publishing — but needed longer than ``timeout`` to cover the whole
    clock gap — was declared dead mid-progress.  The deadline must reset on
    every observed clock advance, so ``PeerTimeout`` fires only after
    ``timeout`` seconds of *zero* progress (a corpse).
  * ``read_at_most`` raced the peer's own ``keep=`` pruning: a round listed
    by ``rounds()`` could be deleted before ``read()`` opened it, escaping
    as ``FileNotFoundError`` between rounds.  A pruned miss is retried
    against a fresh scan; ``None`` only when nothing ≤ the bound remains.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.exchange import ParamStore, PeerTimeout


def tree(v: float):
    return {"w": np.full(4, v, np.float32)}


# --------------------------------------------------------------------------- #
# wait_clock: straggler vs corpse
# --------------------------------------------------------------------------- #
def test_wait_clock_waits_out_slow_but_alive_straggler(tmp_path):
    """Peer publishes one round every ~0.12s with timeout=0.3: each single
    gap is inside the timeout but the TOTAL distance to the target clock is
    far beyond it.  Under the old fixed deadline this raised PeerTimeout
    mid-progress; with the per-advance reset the straggler is waited out."""
    root = str(tmp_path)
    a = ParamStore(root, 0, 2, timeout=0.3, poll=0.005)
    b = ParamStore(root, 1, 2, timeout=0.3, poll=0.005)
    target = 8  # 8 * 0.12s ≈ 1s of publishing >> the 0.3s timeout

    def straggle():
        for r in range(target):
            time.sleep(0.12)
            b.publish(r, tree(r))

    t = threading.Thread(target=straggle)
    t.start()
    try:
        assert a.wait_clock(1, target) >= target
    finally:
        t.join()


def test_wait_clock_still_times_out_on_frozen_clock(tmp_path):
    """A corpse — clock frozen short of the target — must still raise
    after ~timeout seconds of zero progress (progress made BEFORE the
    freeze must not extend the grace period indefinitely)."""
    root = str(tmp_path)
    a = ParamStore(root, 0, 2, timeout=0.25, poll=0.005)
    b = ParamStore(root, 1, 2)
    b.publish(0, tree(0.0))
    b.publish(1, tree(1.0))  # clock = 2, then silence
    t0 = time.monotonic()
    with pytest.raises(PeerTimeout) as err:
        a.wait_clock(1, 5)
    elapsed = time.monotonic() - t0
    assert err.value.peer == 1
    assert 0.2 <= elapsed < 2.0  # one timeout window, not poll-forever


def test_wait_clock_timeout_measures_silence_not_total_wait(tmp_path):
    """Progress at t≈0.15 then silence: the total wait exceeds one timeout
    window, but the raise must come ~timeout after the LAST advance, and
    the error must name the still-missing round."""
    root = str(tmp_path)
    a = ParamStore(root, 0, 2, timeout=0.3, poll=0.005)
    b = ParamStore(root, 1, 2)
    b.publish(0, tree(0.0))

    def one_late_publish():
        time.sleep(0.15)
        b.publish(1, tree(1.0))  # clock 1 -> 2, then a corpse

    t = threading.Thread(target=one_late_publish)
    t.start()
    t0 = time.monotonic()
    with pytest.raises(PeerTimeout):
        a.wait_clock(1, 4)
    elapsed = time.monotonic() - t0
    t.join()
    # deadline reset at the t≈0.15 advance: total ≈ 0.15 + 0.3, clearly
    # more than one bare window and far less than two-from-start
    assert elapsed >= 0.3


def test_wait_clock_returns_early_for_departed_peer(tmp_path):
    """LEFT markers still short-circuit the wait (no regression): a
    graceful departure returns the final clock instead of timing out."""
    root = str(tmp_path)
    a = ParamStore(root, 0, 2, timeout=5.0, poll=0.005)
    b = ParamStore(root, 1, 2)
    b.publish(0, tree(0.0))
    b.mark_left()
    t0 = time.monotonic()
    assert a.wait_clock(1, 100) == 1
    assert time.monotonic() - t0 < 1.0


# --------------------------------------------------------------------------- #
# read_at_most: racing the peer's pruning
# --------------------------------------------------------------------------- #
def test_read_at_most_retries_when_listed_round_is_pruned(tmp_path):
    """Injected race: the first scan lists rounds {0..3}, then round 3's
    file vanishes (peer pruning) before the read.  The old code let the
    FileNotFoundError escape; now the rescan falls back to the freshest
    survivor ≤ the bound."""
    root = str(tmp_path)
    a = ParamStore(root, 0, 2)
    b = ParamStore(root, 1, 2)
    for r in range(4):
        b.publish(r, tree(float(r)))

    real_rounds = a.rounds
    state = {"pruned": False}

    def racing_rounds(host):
        out = real_rounds(host)
        if not state["pruned"]:
            # delete the newest listed file AFTER the scan, BEFORE the read
            state["pruned"] = True
            os.unlink(os.path.join(root, "h1", f"step_{out[-1]}.npz"))
        return out

    a.rounds = racing_rounds  # inject the race on the reader side
    got = a.read_at_most(1, 3, tree(0.0))
    assert got is not None
    restored, r = got
    assert r == 2  # freshest survivor within the bound
    np.testing.assert_allclose(restored["w"], np.full(4, 2.0, np.float32))


def test_read_at_most_returns_none_when_everything_pruned(tmp_path):
    """When the rescan shows nothing ≤ the bound remains, the answer is
    None — not an exception and not an infinite retry loop."""
    root = str(tmp_path)
    a = ParamStore(root, 0, 2)
    b = ParamStore(root, 1, 2)
    for r in range(3):
        b.publish(r, tree(float(r)))

    real_rounds = a.rounds

    def racing_rounds(host):
        out = real_rounds(host)
        # every listed round vanishes before the read, every time
        for rr in out:
            f = os.path.join(root, "h1", f"step_{rr}.npz")
            if os.path.exists(f):
                os.unlink(f)
        return out

    a.rounds = racing_rounds
    assert a.read_at_most(1, 2, tree(0.0)) is None


def test_read_at_most_survives_repeated_pruning_races(tmp_path):
    """Several consecutive scans each lose their newest listed round to
    pruning; the retry loop must keep falling back (never re-targeting a
    deleted round) and land on the oldest survivor."""
    root = str(tmp_path)
    a = ParamStore(root, 0, 2)
    b = ParamStore(root, 1, 2)
    for r in range(5):
        b.publish(r, tree(float(r)))

    real_rounds = a.rounds
    state = {"races": 0}

    def racing_rounds(host):
        out = real_rounds(host)
        if state["races"] < 3 and len(out) > 1:
            state["races"] += 1
            os.unlink(os.path.join(root, "h1", f"step_{out[-1]}.npz"))
        return out

    a.rounds = racing_rounds
    got = a.read_at_most(1, 4, tree(0.0))
    assert got is not None
    restored, r = got
    assert r == 1 and state["races"] == 3
    np.testing.assert_allclose(restored["w"], np.full(4, 1.0, np.float32))
