"""Sharding rules: logical-axis mapping, divisibility fallback, batch specs,
collective-bytes HLO parser, and the dry-run's abstract-state builders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import (DEFAULT_RULES, ShardingRules, logical_to_spec,
                                  shardings_for)


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)    # 1 CPU device, both axes size 1


class TestLogicalToSpec:
    def test_divisible_maps(self, mesh):
        spec = logical_to_spec(("embed", "ffn"), (64, 128), mesh, DEFAULT_RULES)
        assert spec == P("data", "model")     # size-1 axes always divide

    def test_indivisible_drops(self):
        mesh = make_host_mesh(1, 1)
        # fake a bigger mesh via a rules table targeting a missing axis
        rules = ShardingRules((("ffn", "missing_axis"),))
        spec = logical_to_spec(("ffn",), (100,), mesh, rules)
        assert spec == P(None)

    def test_axis_used_once(self, mesh):
        """Two dims mapping to the same mesh axis: only the first binds."""
        spec = logical_to_spec(("embed", "embed"), (64, 64), mesh, DEFAULT_RULES)
        assert spec == P("data", None)

    def test_none_passthrough(self, mesh):
        spec = logical_to_spec((None, "heads"), (3, 4), mesh, DEFAULT_RULES)
        assert spec[0] is None

    def test_dropped_diagnostics(self):
        mesh = make_host_mesh(1, 1)
        rules = ShardingRules((("ffn", "model"),))
        dropped = []
        # dim 7 % 1 == 0 — size-1 axis always divides, so no drop on this
        # mesh; the diagnostic list stays empty
        logical_to_spec(("ffn",), (7,), mesh, rules, dropped)
        assert dropped == []


class TestShardingsFor:
    def test_tree_structure_preserved(self, mesh):
        params = {"a": jnp.zeros((8, 4)), "b": {"c": jnp.zeros((4,))}}
        axes = {"a": ("embed", "ffn"), "b": {"c": ("ffn",)}}
        sh = shardings_for(axes, params, mesh, DEFAULT_RULES)
        assert set(sh) == {"a", "b"}
        assert sh["a"].spec == P("data", "model")
        assert sh["b"]["c"].spec == P("model")


class TestCollectiveBytesParser:
    def test_parses_known_hlo(self):
        from repro.launch.dryrun import collective_bytes
        hlo = """
  %ar = f32[1024,16]{1,0} all-reduce(f32[1024,16]{1,0} %x), replica_groups={}
  %ag = bf16[64,128]{1,0} all-gather(bf16[8,128]{1,0} %y), dimensions={0}
  %rs = f32[32]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
  %cp = s32[16]{0} collective-permute(s32[16]{0} %w), source_target_pairs={{0,1}}
  %notacoll = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
        out = collective_bytes(hlo)
        assert out["bytes_by_op"]["all-reduce"] == 1024 * 16 * 4
        assert out["bytes_by_op"]["all-gather"] == 64 * 128 * 2
        assert out["bytes_by_op"]["reduce-scatter"] == 32 * 4
        assert out["bytes_by_op"]["collective-permute"] == 16 * 4
        assert out["count_by_op"]["all-to-all"] == 0
        assert out["total_bytes"] == sum(out["bytes_by_op"].values())

    def test_tuple_result_shapes(self):
        from repro.launch.dryrun import collective_bytes
        hlo = "%ar = (f32[8]{0}, f32[16]{0}) all-reduce(%a, %b), to_apply=%sum"
        out = collective_bytes(hlo)
        assert out["bytes_by_op"]["all-reduce"] == (8 + 16) * 4


class TestAbstractBuilders:
    def test_abstract_params_no_allocation(self):
        """A 17B-param arch must be abstractable instantly (structs only)."""
        from repro.launch.dryrun import abstract_params
        cfg = get_config("llama4-scout-17b-16e")
        params_s, axes = abstract_params(cfg)
        leaves = jax.tree.leaves(params_s)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        n_params = sum(int(np.prod(l.shape)) for l in leaves)
        assert n_params > 15e9        # 16 experts: ~100B total, 17B active
        ax_leaves = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
        assert len(ax_leaves) > 0

    def test_model_flops_estimate_sane(self):
        from repro.configs import SHAPES
        from repro.launch.dryrun import model_flops_estimate
        cfg = get_config("granite-3-8b")
        f_train = model_flops_estimate(cfg, SHAPES["train_4k"])
        # 6 * ~8e9 params * 1M tokens ≈ 5e16
        assert 1e16 < f_train < 1e17
        f_dec = model_flops_estimate(cfg, SHAPES["decode_32k"])
        assert f_dec < f_train / 1000


@settings(max_examples=30, deadline=None)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       names=st.lists(st.sampled_from(["embed", "ffn", "heads", "batch", None]),
                      min_size=1, max_size=4))
def test_logical_to_spec_total_property(dims, names):
    """Any (shape, axes) pair yields a valid PartitionSpec: same rank, every
    mesh axis used at most once."""
    mesh = make_host_mesh(1, 1)
    n = min(len(dims), len(names))
    spec = logical_to_spec(tuple(names[:n]), tuple(dims[:n]), mesh, DEFAULT_RULES)
    assert len(spec) == n
    used = [s for s in spec if s is not None]
    flat = []
    for u in used:
        flat.extend(u if isinstance(u, tuple) else (u,))
    assert len(flat) == len(set(flat))
