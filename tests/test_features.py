"""Feature-extraction tier: device-side transforms and the hashing
vectorizer (the streaming companion of the Fig. A2 path)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.mltable import MLTable
from repro.core.numeric_table import MLNumericTable
from repro.features.scaling import add_bias, standardize
from repro.features.text import hashing_vectorizer, n_grams


class TestStandardize:
    def test_zero_mean_unit_std(self, rng):
        X = np.asarray(rng.normal(3.0, 2.5, size=(64, 5)), np.float32)
        t = standardize(MLNumericTable.from_numpy(X, num_shards=4))
        out = np.asarray(t.data)
        np.testing.assert_allclose(out.mean(0), 0.0, atol=1e-4)
        np.testing.assert_allclose(out.std(0), 1.0, atol=1e-2)

    def test_shard_invariant(self, rng):
        X = np.asarray(rng.normal(size=(24, 3)), np.float32)
        a = np.asarray(standardize(MLNumericTable.from_numpy(X, num_shards=1)).data)
        b = np.asarray(standardize(MLNumericTable.from_numpy(X, num_shards=4)).data)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


class TestAddBias:
    def test_inserts_ones(self, rng):
        X = np.asarray(rng.normal(size=(8, 3)), np.float32)
        t = add_bias(MLNumericTable.from_numpy(X, num_shards=2), at=1)
        out = np.asarray(t.data)
        assert out.shape == (8, 4)
        np.testing.assert_array_equal(out[:, 1], 1.0)
        np.testing.assert_allclose(out[:, 0], X[:, 0])
        np.testing.assert_allclose(out[:, 2:], X[:, 1:])


class TestHashingVectorizer:
    def test_fixed_width_output(self):
        docs = ["a b c", "c d e f", "a a a"]
        t = MLTable.from_text(docs, num_partitions=2)
        out = hashing_vectorizer(t, num_features=32)
        assert out.num_rows == 3 and out.num_cols == 32
        X = np.asarray(out.to_numeric(num_shards=1).data)
        # doc 2 is three copies of one token -> single bucket with count 3
        assert X[2].max() == 3.0 and (X[2] > 0).sum() == 1

    def test_deterministic(self):
        docs = ["the quick brown fox"]
        t = MLTable.from_text(docs, num_partitions=1)
        a = np.asarray(hashing_vectorizer(t, num_features=64).to_numeric(1).data)
        b = np.asarray(hashing_vectorizer(t, num_features=64).to_numeric(1).data)
        np.testing.assert_array_equal(a, b)


class TestNGrams:
    def test_bigram_extraction(self):
        t = MLTable.from_text(["a b c", "b c d"], num_partitions=1)
        out = n_grams(t, n=2, top=10)
        names = [n for n in out.schema.names if n]
        assert "b c" in names          # shared bigram survives the top-k cut

    @settings(max_examples=10, deadline=None)
    @given(parts=st.integers(1, 4))
    def test_partition_invariance(self, parts):
        docs = ["x y z w", "y z w v", "z w v u"]
        base = np.asarray(
            n_grams(MLTable.from_text(docs, num_partitions=1), n=2, top=8)
            .to_numeric(1).data)
        got = np.asarray(
            n_grams(MLTable.from_text(docs, num_partitions=parts), n=2, top=8)
            .to_numeric(1).data)
        np.testing.assert_array_equal(base, got)
