"""Host-trie tests for ``repro.serve.prefix_cache`` — unit coverage plus
the property suite over random op interleavings (hypothesis when
installed, the deterministic fallback otherwise).  Device-half behaviour
(restore/extract exactness through a real model) lives in
``tests/test_serve_prefix.py``."""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.serve.prefix_cache import RadixPrefixCache  # noqa: E402


def _toks(seed, n):
    return np.random.default_rng(seed).integers(0, 1000, size=n).astype(np.int32)


# --------------------------------------------------------------------------- #
# unit coverage
# --------------------------------------------------------------------------- #
class TestTrieUnits:
    def test_miss_then_insert_then_hit(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=16)
        toks = _toks(0, 17)
        m = pc.match(toks)
        assert m.length == 0 and m.nodes == ()
        pc.release(m)
        writes = pc.plan_insert(toks)
        assert [s for _, s in writes] == [0, 4, 8, 12]   # 4 full blocks
        m = pc.match(toks)
        # the last *matchable* block is capped so >= 1 tail token remains
        assert m.length == 16
        pc.release(m)

    def test_full_prompt_match_leaves_tail_token(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=16)
        toks = _toks(1, 16)                              # exactly 4 blocks
        pc.plan_insert(toks)
        m = pc.match(toks)
        assert m.length == 12                            # (16-1)//4 blocks
        pc.release(m)

    def test_match_is_block_aligned_prefix(self):
        pc = RadixPrefixCache(block_size=8, capacity_blocks=16)
        toks = _toks(2, 30)
        pc.plan_insert(toks)
        other = toks.copy()
        other[20] += 1                                   # diverge in block 2
        m = pc.match(other)
        assert m.length == 16                            # blocks 0-1 only
        pc.release(m)

    def test_release_twice_raises(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=8)
        toks = _toks(3, 9)
        pc.plan_insert(toks)
        m = pc.match(toks)
        pc.release(m)
        with pytest.raises(RuntimeError):
            pc.release(m)

    def test_valid_end_shrinks_on_shorter_reinsert(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=8)
        long = _toks(4, 12)
        pc.plan_insert(long)
        node = pc._root.children[long[:4].tobytes()]
        assert node.valid_end == 12
        writes = pc.plan_insert(long[:8])                # shorter prefix
        assert node.valid_end == 8
        assert (node.block_id, 0) in writes              # pool rewrite queued

    def test_ring_truncation(self):
        """A windowed ring keeps only the last ``ring`` positions of the
        extraction, so a match must drop blocks whose needed positions fall
        in the garbage region."""
        pc = RadixPrefixCache(block_size=4, capacity_blocks=16,
                              ring_sizes=(8,))
        toks = _toks(5, 17)
        pc.plan_insert(toks)                             # valid_end = 17
        # matching 16 needs positions [8, 16); garbage is [0, 17-8=9):
        # block 2 (positions 8..11) overlaps → no usable prefix at all
        # (shorter matches need even earlier positions)
        assert pc.peek(toks) == 0
        pc.plan_insert(toks[:8])                         # valid_end -> 8
        m = pc.match(toks)
        # blocks 0-1 now fully valid for ring 8; blocks 2-3 still garbage
        assert m.length == 8
        pc.release(m)

    def test_global_ring_never_truncates(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=16,
                              ring_sizes=(64,))
        toks = _toks(6, 17)
        pc.plan_insert(toks)
        assert pc.peek(toks) == 16

    def test_eviction_prefers_lru_unreferenced_leaf(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=2)
        a, b = _toks(7, 5), _toks(8, 5)
        pc.plan_insert(a)
        pc.plan_insert(b)
        assert pc.blocks == 2
        pc.match(b).nodes                                # touch b's LRU clock
        pc.release(pc.match(b))
        c = _toks(9, 5)
        pc.plan_insert(c)                                # evicts a (oldest)
        assert pc.peek(a) == 0 and pc.peek(b) == 4 and pc.peek(c) == 4
        assert pc.evictions >= 1

    def test_pinned_blocks_never_evicted(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=2)
        a = _toks(10, 9)
        pc.plan_insert(a)                                # fills capacity
        m = pc.match(a)                                  # pins both blocks
        writes = pc.plan_insert(_toks(11, 9))            # nothing evictable
        assert writes == []
        assert pc.peek(a) == 8                           # chain intact
        pc.release(m)

    def test_insert_does_not_evict_own_fresh_blocks(self):
        """Allocating block d+1 under pressure must never evict the
        freshly inserted (still unreferenced leaf) block d of the same
        prompt — the path is pinned for the duration of the insert."""
        pc = RadixPrefixCache(block_size=4, capacity_blocks=3)
        toks = _toks(12, 13)                             # wants 3 blocks
        writes = pc.plan_insert(toks)
        assert len(writes) == 3
        assert pc.peek(toks) == 12                       # whole chain alive

    def test_reset_clears_trie_and_stats(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=8)
        toks = _toks(13, 9)
        pc.plan_insert(toks)
        pc.release(pc.match(toks))
        pc.reset()
        assert pc.blocks == 0 and pc.stats()["requests"] == 0
        assert pc.peek(toks) == 0

    def test_stats_shape(self):
        pc = RadixPrefixCache(block_size=4, capacity_blocks=8)
        toks = _toks(14, 9)
        pc.release(pc.match(toks))
        pc.plan_insert(toks)
        pc.release(pc.match(toks))
        s = pc.stats()
        assert s["requests"] == 2 and s["hits"] == 1 and s["misses"] == 1
        assert s["cached_tokens"] == 8 and s["prompt_tokens"] == 18
        assert 0.0 < s["hit_rate"] < 1.0

    def test_ctor_validation(self):
        with pytest.raises(ValueError):
            RadixPrefixCache(block_size=0)
        with pytest.raises(ValueError):
            RadixPrefixCache(capacity_blocks=0)


# --------------------------------------------------------------------------- #
# property suite: random interleavings of match / release / insert
# --------------------------------------------------------------------------- #
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_interleaving_invariants(seed):
    """Under random match/plan_insert/release/reset interleavings over a
    small token universe (forcing shared prefixes, evictions, and ring
    truncation):

      * every match is a block-aligned true prefix of the probe, shorter
        than the probe (>= 1 tail token);
      * refcounts never go negative and pinned chains survive eviction
        pressure (their tokens still match while pinned);
      * live blocks never exceed ``capacity_blocks``;
      * insert/match round-trip: right after a successful full insert, the
        prompt matches to its full matchable length unless a ring's
        validity rule forbids it.
    """
    rng = np.random.default_rng(seed)
    bs = int(rng.choice([2, 4]))
    cap = int(rng.choice([3, 6, 12]))
    rings = [(), (2 * bs,), (2 * bs, 64)][rng.integers(0, 3)]
    pc = RadixPrefixCache(block_size=bs, capacity_blocks=cap,
                          ring_sizes=rings)
    # tiny universe: 3 base prompts + random perturbations → heavy sharing
    bases = [rng.integers(0, 5, size=int(rng.integers(bs, 6 * bs)))
             .astype(np.int32) for _ in range(3)]
    pinned = []                                          # (match, tokens)
    for _ in range(60):
        op = rng.integers(0, 10)
        toks = bases[rng.integers(0, 3)].copy()
        if rng.random() < 0.3 and len(toks) > 1:
            toks[rng.integers(0, len(toks))] += 1
        if op < 4:                                       # match (and pin)
            m = pc.match(toks)
            assert m.length % bs == 0
            assert m.length <= (len(toks) - 1) // bs * bs
            assert all(n.refs > 0 for n in m.nodes)
            pinned.append((m, toks))
        elif op < 7:                                     # insert
            writes = pc.plan_insert(toks)
            assert len({bid for bid, _ in writes}) == len(writes)
            if not rings and len(writes) == len(toks) // bs:
                # full insert + no ring rules → full round-trip
                assert pc.peek(toks) == (len(toks) - 1) // bs * bs
        elif op < 9 and pinned:                          # release one pin
            m, _ = pinned.pop(rng.integers(0, len(pinned)))
            pc.release(m)
        elif op == 9 and not pinned and rng.random() < 0.1:
            pc.reset()
        # global invariants after every op
        assert pc.blocks <= cap
        assert all(n.refs >= 0 for n in pc._registry)
        for m, toks in pinned:                           # pins survive
            assert all(n in pc._registry for n in m.nodes)
            assert np.array_equal(
                np.concatenate([np.frombuffer(n.key, np.int32)
                                for n in m.nodes])
                if m.nodes else np.empty(0, np.int32),
                toks[:m.length])
    for m, _ in pinned:
        pc.release(m)
    assert all(n.refs == 0 for n in pc._registry)
