"""The instance-based Estimator contract: legacy classmethod shims are
bit-identical and warn; every core algorithm fits as an instance; fitted
``partial`` state rebuilds the model exactly."""
import warnings

import numpy as np
import pytest

from repro.core.algorithms.als import ALSParameters, BroadcastALS, \
    pack_csr_table
from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.algorithms.linear_models import (
    LinearRegressionAlgorithm,
    LinearSVMAlgorithm,
)
from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm,
    LogisticRegressionParameters,
)
from repro.core.algorithms.naive_bayes import GaussianNaiveBayes, \
    NaiveBayesParameters
from repro.core.algorithms.pca import PCA, PCAParameters
from repro.core.numeric_table import MLNumericTable


def _logreg_table(rng, n=64, d=6):
    w = np.linspace(-1, 1, d).astype(np.float32)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    return MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                     num_shards=4)


class TestDeprecationShims:
    def test_train_warns_and_is_bit_identical(self, rng):
        t = _logreg_table(rng)
        p = LogisticRegressionParameters(learning_rate=0.3, max_iter=6)
        with pytest.warns(DeprecationWarning, match="deprecated"):
            old = LogisticRegressionAlgorithm.train(t, p)
        new = LogisticRegressionAlgorithm(p).fit(t)
        np.testing.assert_array_equal(np.asarray(old.weights),
                                      np.asarray(new.weights))

    def test_default_parameters_spelling_warns(self):
        with pytest.warns(DeprecationWarning, match="defaultParameters"):
            p = KMeans.defaultParameters()
        assert p == KMeans.default_parameters() == KMeansParameters()

    def test_kmeans_shim_bit_identical(self, rng):
        X = np.asarray(rng.normal(size=(32, 4)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        p = KMeansParameters(k=3, max_iter=5, seed=1)
        with pytest.warns(DeprecationWarning):
            old = KMeans.train(t, p)
        new = KMeans(p).fit(t)
        np.testing.assert_array_equal(np.asarray(old.centroids),
                                      np.asarray(new.centroids))

    def test_als_shim_passes_transposed_positionally(self, rng):
        rows = np.repeat(np.arange(8), 4)
        cols = np.tile(np.arange(4), 8)
        vals = rng.uniform(1, 5, size=rows.size).astype(np.float32)
        data = pack_csr_table(rows, cols, vals, 8, 4, num_shards=2)
        data_t = pack_csr_table(cols, rows, vals, 4, 8, num_shards=2)
        p = ALSParameters(rank=2, max_iter=2)
        with pytest.warns(DeprecationWarning):
            old = BroadcastALS.train(data, p, data_t)
        new = BroadcastALS(p).fit(data, data_transposed=data_t)
        np.testing.assert_array_equal(np.asarray(old.U), np.asarray(new.U))
        np.testing.assert_array_equal(np.asarray(old.V), np.asarray(new.V))

    def test_train_stream_shim_matches_fit_stream(self, rng):
        from repro.data import BatchIterator

        def source(step):
            g = np.random.default_rng(7 * step + 1)
            X = g.normal(size=(32, 4)).astype(np.float32)
            y = (X.sum(1) > 0).astype(np.float32)
            return {"data": np.concatenate([y[:, None], X], 1)}

        p = LogisticRegressionParameters(learning_rate=0.2, max_iter=3)
        old = LogisticRegressionAlgorithm.train_stream(
            BatchIterator(source), p, num_epochs=3, num_shards=2)
        new = LogisticRegressionAlgorithm(p).fit_stream(
            BatchIterator(source), num_epochs=3, num_shards=2)
        np.testing.assert_array_equal(np.asarray(old.weights),
                                      np.asarray(new.weights))


class TestEstimatorInstances:
    def test_constructor_overrides(self):
        est = LogisticRegressionAlgorithm(learning_rate=0.9, l2=0.01)
        assert est.params.learning_rate == 0.9
        assert est.params.l2 == 0.01
        assert est.overrides() == {"learning_rate": 0.9, "l2": 0.01}

    def test_params_dataclass_plus_overrides(self):
        est = KMeans(KMeansParameters(k=5), seed=3)
        assert est.params.k == 5 and est.params.seed == 3

    def test_unknown_field_raises(self):
        with pytest.raises(TypeError):
            LogisticRegressionAlgorithm(not_a_field=1)

    @pytest.mark.parametrize("make", [
        lambda t, rng: LogisticRegressionAlgorithm(max_iter=4).fit(t),
        lambda t, rng: LinearRegressionAlgorithm(max_iter=4).fit(t),
        lambda t, rng: GaussianNaiveBayes(
            NaiveBayesParameters(num_classes=2)).fit(t),
    ])
    def test_supervised_estimators_fit(self, rng, make):
        model = make(_logreg_table(rng), rng)
        X = np.asarray(rng.normal(size=(8, 6)), np.float32)
        out = np.asarray(model.predict(X))
        assert out.shape[0] == 8

    def test_svm_fits_pm1_labels(self, rng):
        d = 4
        X = np.asarray(rng.normal(size=(32, d)), np.float32)
        y = np.sign(X.sum(1)).astype(np.float32)
        t = MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                      num_shards=2)
        model = LinearSVMAlgorithm(max_iter=4).fit(t)
        assert np.asarray(model.predict(X)).shape == (32,)

    def test_pca_and_kmeans_fit(self, rng):
        X = np.asarray(rng.normal(size=(32, 5)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        pca = PCA(PCAParameters(n_components=2)).fit(t)
        assert np.asarray(pca.transform(X)).shape == (32, 2)
        km = KMeans(k=3, max_iter=4).fit(t)
        assert np.asarray(km.predict(X)).shape == (32,)


class TestPartialRebuild:
    """`partial` exposes the fitted state; `rebuild` reconstructs the
    fitted object exactly — the contract pipeline checkpoints ride on."""

    def test_logreg_round_trip(self, rng):
        t = _logreg_table(rng)
        est = LogisticRegressionAlgorithm(max_iter=4)
        model = est.fit(t)
        clone = est.rebuild(model.partial)
        X = np.asarray(rng.normal(size=(8, 6)), np.float32)
        np.testing.assert_array_equal(np.asarray(model.predict(X)),
                                      np.asarray(clone.predict(X)))

    def test_all_partials_are_array_trees(self, rng):
        import jax

        t = _logreg_table(rng)
        models = [
            LogisticRegressionAlgorithm(max_iter=2).fit(t),
            GaussianNaiveBayes(NaiveBayesParameters(num_classes=2)).fit(t),
            PCA(PCAParameters(n_components=2)).fit(t),
            KMeans(k=2, max_iter=2).fit(t),
        ]
        for m in models:
            leaves = jax.tree.leaves(m.partial)
            assert leaves, f"{type(m).__name__} partial has no leaves"
            for leaf in leaves:
                assert hasattr(leaf, "shape")

    def test_kmeans_rebuild_round_trip(self, rng):
        X = np.asarray(rng.normal(size=(32, 4)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=2)
        est = KMeans(k=3, max_iter=4, seed=2)
        model = est.fit(t)
        clone = est.rebuild(model.partial)
        np.testing.assert_array_equal(np.asarray(model.centroids),
                                      np.asarray(clone.centroids))
        assert clone.centroids.dtype == model.centroids.dtype
