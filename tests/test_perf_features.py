"""§Perf feature tests: chunked cross-entropy, gather MoE dispatch,
serving rules/mesh — the beyond-paper optimizations must be exact."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke
from repro.models.layers.moe import moe_apply, moe_init
from repro.sharding.rules import DEFAULT_RULES, SERVE_RULES
from repro.train.loss import (chunked_cross_entropy_from_hidden,
                              cross_entropy_loss)
from repro.train.step import init_train_state, make_train_step


class TestChunkedCrossEntropy:
    def _setup(self, N=48, D=16, V=256, seed=0):
        rng = np.random.default_rng(seed)
        hidden = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
        table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, V, size=N), jnp.int32)
        return hidden, table, labels

    @pytest.mark.parametrize("chunk", [32, 64, 256])
    def test_matches_reference(self, chunk):
        hidden, table, labels = self._setup()
        ref = cross_entropy_loss((hidden @ table.T)[None], labels[None])
        got = chunked_cross_entropy_from_hidden(hidden, table, labels,
                                                chunk=chunk)
        np.testing.assert_allclose(float(got), float(ref), rtol=1e-6)

    def test_gradients_match(self):
        hidden, table, labels = self._setup()
        g_ref = jax.grad(lambda h, t: cross_entropy_loss(
            (h @ t.T)[None], labels[None]), argnums=(0, 1))(hidden, table)
        g_chk = jax.grad(lambda h, t: chunked_cross_entropy_from_hidden(
            h, t, labels, chunk=64), argnums=(0, 1))(hidden, table)
        for a, b in zip(g_ref, g_chk):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-7)

    def test_mask(self):
        hidden, table, labels = self._setup()
        mask = jnp.asarray(np.random.default_rng(1).integers(0, 2, 48),
                           jnp.float32)
        ref = cross_entropy_loss((hidden @ table.T)[None], labels[None],
                                 mask[None])
        got = chunked_cross_entropy_from_hidden(hidden, table, labels,
                                                chunk=64, mask=mask)
        np.testing.assert_allclose(float(got), float(ref), rtol=1e-6)

    def test_indivisible_chunk_falls_back(self):
        hidden, table, labels = self._setup(V=250)
        ref = cross_entropy_loss((hidden @ table.T)[None], labels[None])
        got = chunked_cross_entropy_from_hidden(hidden, table, labels,
                                                chunk=64)
        np.testing.assert_allclose(float(got), float(ref), rtol=1e-6)

    def test_train_step_with_chunked_loss(self):
        cfg = dataclasses.replace(get_smoke("qwen2-1.5b"),
                                  loss_vocab_chunk=128)
        state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
        step = make_train_step(cfg)
        batch = {"tokens": jnp.ones((2, 32), jnp.int32),
                 "labels": jnp.ones((2, 32), jnp.int32)}
        _, m = step(state, batch)
        # must equal the unchunked step's loss exactly
        cfg0 = get_smoke("qwen2-1.5b")
        state0, _ = init_train_state(jax.random.PRNGKey(0), cfg0)
        _, m0 = make_train_step(cfg0)(state0, batch)
        np.testing.assert_allclose(float(m["loss"]), float(m0["loss"]),
                                   rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([16, 32, 128]))
def test_chunked_xent_property(seed, chunk):
    rng = np.random.default_rng(seed)
    N, D, V = 16, 8, 128
    hidden = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=N), jnp.int32)
    ref = cross_entropy_loss((hidden @ table.T)[None], labels[None])
    got = chunked_cross_entropy_from_hidden(hidden, table, labels, chunk=chunk)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


class TestGatherDispatch:
    @pytest.mark.parametrize("arch", ["mixtral-8x22b", "llama4-scout-17b-16e"])
    def test_matches_einsum_path(self, arch):
        cfg = get_smoke(arch)
        params, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 64, cfg.d_model)), jnp.float32)
        out_e, aux_e = moe_apply(params, x, dataclasses.replace(
            cfg, moe_dispatch="einsum"))
        out_g, aux_g = moe_apply(params, x, dataclasses.replace(
            cfg, moe_dispatch="gather"))
        np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(aux_e), float(aux_g), rtol=1e-6)

    def test_gradients_flow(self):
        cfg = dataclasses.replace(get_smoke("mixtral-8x22b"),
                                  moe_dispatch="gather")
        params, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(1, 32, cfg.d_model)), jnp.float32)

        def loss(p):
            out, aux = moe_apply(p, x, cfg)
            return jnp.sum(out ** 2) + aux

        grads = jax.grad(loss)(params)
        gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0

    def test_train_step_with_gather_dispatch(self):
        cfg = dataclasses.replace(get_smoke("mixtral-8x22b"),
                                  moe_dispatch="gather")
        state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
        _, m = make_train_step(cfg)(state, {
            "tokens": jnp.ones((2, 64), jnp.int32),
            "labels": jnp.ones((2, 64), jnp.int32)})
        assert np.isfinite(float(m["loss"]))


class TestServingRules:
    def test_serve_rules_drop_fsdp(self):
        assert DEFAULT_RULES.lookup("embed") == "data"
        assert SERVE_RULES.lookup("embed") is None
        assert SERVE_RULES.lookup("kv_seq") == ("data", "model")
        # model-parallel mappings intact
        assert SERVE_RULES.lookup("ffn") == "model"

    def test_serving_mesh_factorization(self):
        from repro.launch.mesh import make_serving_mesh
        # 1 CPU device: can't build 256-chip meshes here; verify the
        # arithmetic instead (the dry-run subprocess exercises the real one)
        import inspect
        src = inspect.getsource(make_serving_mesh)
        assert "(32, 8)" in src or "model: int = 8" in src


def test_serving_setup_per_arch():
    """EXPERIMENTS.md §Perf adoption rule: GQA archs get the serving mesh +
    SERVE_RULES; recurrent/SSM archs keep training defaults."""
    from repro.configs import get_config
    from repro.launch.mesh import serving_setup
    import inspect
    src = inspect.getsource(serving_setup)
    # structural check only (1 CPU device here, mesh build runs in dry-run)
    assert "SERVE_RULES" in src and "RGLRU" in src
