"""int8 quantized matmul: Pallas kernel vs the ``ref`` oracle (bit-exact),
the ``ops`` wrapper fallback, and the QTensor / model-quantization layer.

The acceptance contract (ISSUE 8): the kernel must be *bit-exact* against
``ref.quant_matmul_ref`` — both accumulate the int8×int8 products in
int32, which is order-independent, so there is no tolerance to hide
behind.  The CPU fallback in ``ops.quant_matmul`` accumulates in f32
instead (no int32 MXU off-TPU); that is exact as long as
K · 127² < 2²⁴ ≈ K ≲ 1000, which every test and smoke model here obeys.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import quant_matmul, quantize_rows
from repro.kernels.quant_matmul import quant_matmul_pallas

RNG = np.random.default_rng(11)


def _qpair(m, k, n):
    xq = jnp.asarray(RNG.integers(-127, 128, size=(m, k)), jnp.int8)
    wq = jnp.asarray(RNG.integers(-127, 128, size=(k, n)), jnp.int8)
    xs = jnp.asarray(RNG.uniform(1e-3, 2e-2, size=(m,)), jnp.float32)
    ws = jnp.asarray(RNG.uniform(1e-3, 2e-2, size=(n,)), jnp.float32)
    return xq, xs, wq, ws


class TestQuantMatmulKernel:
    @pytest.mark.parametrize("m,k,n,bm,bn,bk", [
        (8, 128, 128, 8, 128, 128),
        (16, 256, 512, 8, 256, 128),
        (32, 384, 256, 16, 128, 128),
        (256, 512, 256, 128, 256, 256),
    ])
    def test_bit_exact_vs_ref(self, m, k, n, bm, bn, bk):
        xq, xs, wq, ws = _qpair(m, k, n)
        got = quant_matmul_pallas(xq, xs, wq, ws, block_m=bm, block_n=bn,
                                  block_k=bk, interpret=True)
        expect = ref.quant_matmul_ref(xq, xs, wq, ws)
        # bit-exact: int32 accumulation then one scale multiply, in both
        assert np.array_equal(np.asarray(got), np.asarray(expect))

    def test_extreme_values_no_overflow(self):
        """±127 everywhere at K=512: |acc| = 512·127² ≈ 8.3e6 < 2³¹."""
        m, k, n = 8, 512, 128
        xq = jnp.full((m, k), 127, jnp.int8)
        wq = jnp.full((k, n), -127, jnp.int8)
        xs = jnp.ones(m, jnp.float32)
        ws = jnp.ones(n, jnp.float32)
        got = quant_matmul_pallas(xq, xs, wq, ws, block_m=8, block_n=128,
                                  block_k=256, interpret=True)
        assert np.array_equal(np.asarray(got),
                              np.full((m, n), 512 * 127 * -127, np.float32))


class TestQuantMatmulWrapper:
    def test_fallback_matches_ref(self):
        """Off-TPU the wrapper's f32-accumulation path must still equal
        the int32 oracle exactly while K·127² fits f32's 24-bit mantissa."""
        xq, xs, wq, ws = _qpair(24, 320, 96)     # non-tilable on purpose
        got = quant_matmul(xq, xs, wq, ws)
        expect = ref.quant_matmul_ref(xq, xs, wq, ws)
        assert np.array_equal(np.asarray(got), np.asarray(expect))

    def test_shape_validation(self):
        xq, xs, wq, ws = _qpair(8, 64, 32)
        with pytest.raises(ValueError):
            quant_matmul(xq, xs[:4], wq, ws)
        with pytest.raises(ValueError):
            quant_matmul(xq, xs, wq[:32], ws)


class TestQuantizeRows:
    def test_roundtrip_error_half_step(self):
        x = jnp.asarray(RNG.normal(size=(16, 256)) * 3.0, jnp.float32)
        xq, scale = quantize_rows(x)
        assert xq.dtype == jnp.int8 and scale.shape == (16,)
        assert int(jnp.max(jnp.abs(xq))) <= 127
        back = xq.astype(jnp.float32) * scale[:, None]
        err = np.asarray(jnp.max(jnp.abs(back - x), axis=-1))
        # symmetric rounding: worst case half a quantization step per row
        assert np.all(err <= np.asarray(scale) * 0.5 + 1e-6)

    def test_zero_row_stable(self):
        xq, scale = quantize_rows(jnp.zeros((2, 64)))
        assert np.all(np.asarray(xq) == 0) and np.all(np.asarray(scale) > 0)


class TestModelQuantization:
    def test_qtensor_pytree_roundtrip(self):
        from repro.models.layers.quant import QTensor, quantize_weight

        w = jnp.asarray(RNG.normal(size=(4, 64, 32)), jnp.float32)
        qt = quantize_weight(w, n_contract=1, n_batch=1)
        assert isinstance(qt, QTensor) and qt.q.dtype == jnp.int8
        leaves, treedef = jax.tree_util.tree_flatten(qt)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        assert rebuilt.n_contract == qt.n_contract
        assert np.array_equal(np.asarray(rebuilt.q), np.asarray(qt.q))
        # dequantized weight close to original (per-channel half step)
        # scale axes: batch (4,) + output channels (32,)
        deq = qt.q.astype(jnp.float32) * qt.scale[:, None, :]
        assert float(jnp.max(jnp.abs(deq - w))) <= float(
            jnp.max(qt.scale)) * 0.5 + 1e-6

    def test_linear_or_quant_dispatch(self):
        from repro.models.layers.quant import linear_or_quant, quantize_weight

        x = jnp.asarray(RNG.normal(size=(2, 8, 64)), jnp.float32)
        w = jnp.asarray(RNG.normal(size=(64, 32)) * 0.05, jnp.float32)
        exact = linear_or_quant(x, w, "bsd,dk->bsk")
        assert np.allclose(np.asarray(exact),
                           np.asarray(jnp.einsum("bsd,dk->bsk", x, w)))
        qt = quantize_weight(w, n_contract=1)
        approx = linear_or_quant(x, qt, "bsd,dk->bsk")
        assert approx.shape == exact.shape and approx.dtype == exact.dtype
        # int8×int8: relative error bounded by the two half-steps
        rel = float(jnp.max(jnp.abs(approx - exact))) / float(
            jnp.max(jnp.abs(exact)))
        assert rel < 0.05

    def test_quantize_model_params_modes(self):
        from repro.configs import get_smoke
        from repro.models.layers.quant import QTensor, quantize_model_params
        from repro.models.transformer import init_model

        cfg = get_smoke("qwen2-1.5b")
        params, _ = init_model(jax.random.PRNGKey(0), cfg)

        same = quantize_model_params(params, "none")
        assert same is params

        bf = quantize_model_params(params, "bf16")
        assert bf["blocks"]["b0"]["attn"]["wq"].dtype == jnp.bfloat16

        q8 = quantize_model_params(params, "int8")
        attn = q8["blocks"]["b0"]["attn"]
        assert isinstance(attn["wq"], QTensor)
        assert attn["wq"].q.dtype == jnp.int8
        # embeddings / norms untouched: only the projection weights quantize
        assert q8["embed"]["tok"].dtype == params["embed"]["tok"].dtype
        assert q8["final_norm"]["scale"].dtype == jnp.float32
