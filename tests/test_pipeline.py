"""`data/pipeline` coverage: shard_batch placement (divisible vs
non-divisible leading dims, layout agreement with `partition.data_spec`)
and BatchIterator determinism / seek — the properties
DistributedRunner.resume depends on."""
import jax.numpy as jnp
import numpy as np

from repro.data import BatchIterator
from repro.data.pipeline import shard_batch

# --------------------------------------------------------------------------- #
# placement on a real 8-device mesh (subprocess; device count is fixed at
# jax init)
# --------------------------------------------------------------------------- #
_PLACEMENT_PROGRAM = """
import json
import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from repro.core import partition as pt
from repro.core.compat import make_mesh
from repro.core.numeric_table import MLNumericTable
from repro.data.pipeline import shard_batch

assert len(jax.devices()) == 8
mesh = make_mesh((8,), ("data",))
axes = pt.infer_data_axes(mesh)
out = {}

# divisible leading dim -> partitioned over the data axes, features
# replicated: exactly partition.data_spec
b = shard_batch({"data": np.ones((64, 5), np.float32)}, mesh)
out["divisible_matches_data_spec"] = bool(
    b["data"].sharding.spec == pt.data_spec(axes))

# the streamed window and a resident table must have IDENTICAL layouts, so
# the runner can consume either without resharding
table = MLNumericTable.from_numpy(np.ones((64, 5), np.float32), mesh=mesh)
out["agrees_with_resident_table"] = bool(
    b["data"].sharding == table.data.sharding)

# non-divisible leading dim -> fully replicated (no silent padding/drop)
r = shard_batch({"data": np.ones((30, 5), np.float32)}, mesh)
out["nondivisible_replicated"] = bool(r["data"].sharding.is_fully_replicated)

# rank generalization: trailing dims stay replicated at any rank
t3 = shard_batch({"x": np.ones((16, 3, 4), np.float32)}, mesh)
out["rank3_spec"] = bool(t3["x"].sharding.spec == P(axes, None, None))

# per-key independence: one dict can mix partitioned and replicated values
m = shard_batch({"a": np.ones((64, 2), np.float32),
                 "b": np.ones((7,), np.float32)}, mesh)
out["mixed_keys"] = bool(m["a"].sharding.spec == pt.data_spec(axes)
                         and m["b"].sharding.is_fully_replicated)

# values and order survive placement
v = np.arange(64 * 5, dtype=np.float32).reshape(64, 5)
out["values_intact"] = bool(
    np.array_equal(np.asarray(shard_batch({"data": v}, mesh)["data"]), v))
print("RESULT::" + json.dumps(out))
"""


def test_shard_batch_placement_on_mesh(eight_device_run):
    """Divisible windows land row-partitioned with the same spec (and same
    sharding as a resident MLNumericTable); non-divisible windows replicate;
    values are untouched."""
    flags = eight_device_run(_PLACEMENT_PROGRAM)
    bad = [k for k, ok in flags.items() if not ok]
    assert not bad, f"placement checks failed: {bad}"


# --------------------------------------------------------------------------- #
# host-side semantics (single device, in-process)
# --------------------------------------------------------------------------- #
def test_shard_batch_without_mesh_converts_to_jnp(rng):
    b = shard_batch({"data": np.asarray(rng.normal(size=(6, 2)), np.float32)},
                    mesh=None)
    assert isinstance(b["data"], jnp.ndarray)
    assert b["data"].shape == (6, 2)


def _source(step: int):
    rng = np.random.default_rng(100 + step)
    return {"data": rng.normal(size=(8, 3)).astype(np.float32)}


def test_iterator_is_a_pure_function_of_step():
    """Two iterators at the same position must yield identical batches —
    the determinism that makes kill-and-resume exact."""
    a, b = BatchIterator(_source), BatchIterator(_source)
    for _ in range(3):
        np.testing.assert_array_equal(np.asarray(next(a)["data"]),
                                      np.asarray(next(b)["data"]))
    assert a.step == b.step == 3


def test_iterator_seek_restores_position():
    """seek(step) reproduces the exact remaining sequence — what
    DistributedRunner.resume does after restoring checkpoint metadata."""
    it = BatchIterator(_source)
    seen = [np.asarray(next(it)["data"]) for _ in range(4)]
    assert it.step == 4

    resumed = BatchIterator(_source)
    assert resumed.seek(2) is resumed       # chains
    assert resumed.step == 2
    np.testing.assert_array_equal(np.asarray(next(resumed)["data"]), seen[2])
    np.testing.assert_array_equal(np.asarray(next(resumed)["data"]), seen[3])
    assert resumed.step == 4


def test_iterator_start_step_offsets_the_stream():
    it = BatchIterator(_source, start_step=5)
    first = np.asarray(next(it)["data"])
    np.testing.assert_array_equal(first, _source(5)["data"])
    assert it.step == 6
