"""Fleet router, fair queuing, SLO admission, autoscale, quantized decode.

Covers the ISSUE-8 tentpole surface: FairQueue stride scheduling /
priority classes, tenant fairness under a skewed two-tenant trace, SLO
rejection accounting (rejections count as misses), autoscaler hysteresis,
fleet-vs-oracle token parity, and quantized-vs-fp32 decode tolerance.
"""
import itertools

import jax
import numpy as np
import pytest

from repro.analysis import assert_no_retrace
from repro.configs import get_smoke
from repro.models.transformer import init_model
from repro.serve import (FairQueue, QueueAutoscaler, ReplicaRouter, Request,
                         ServeEngine, SlotScheduler, tenant_report)

KEY = jax.random.PRNGKey(1)


def _req(tenant="default", priority=1, arrival=0.0, slo_ms=None, n=4,
         max_new=4, seed=0, vocab=512):
    rng = np.random.default_rng(seed)
    return Request(prompt=rng.integers(0, vocab, size=n).astype(np.int32),
                   max_new_tokens=max_new, tenant=tenant, priority=priority,
                   arrival=arrival, slo_ms=slo_ms)


def _ticking_clock(step=1e-3):
    c = itertools.count()
    return lambda: next(c) * step


# --------------------------------------------------------------------------- #
# FairQueue
# --------------------------------------------------------------------------- #
class TestFairQueue:
    def test_single_tenant_is_push_order_fifo(self):
        """Within one tenant/class the lane is a plain FIFO (callers —
        ``SlotScheduler.release`` — push in arrival order)."""
        q = FairQueue()
        for i, t in enumerate([0.1, 0.2, 0.3]):
            q.push(_req(arrival=t, seed=i))
        assert [q.pop().arrival for _ in range(3)] == [0.1, 0.2, 0.3]

    def test_weighted_interleave(self):
        """weight a:2 b:1 → a served twice as often while both backlogged."""
        q = FairQueue({"a": 2.0, "b": 1.0})
        for i in range(10):
            q.push(_req(tenant="a", arrival=float(i), seed=i))
            q.push(_req(tenant="b", arrival=float(i), seed=i))
        order = "".join(q.pop().tenant for _ in range(15))
        assert order.count("a") == 10 and order.count("b") == 5
        # no starvation: b appears regularly, not only at the tail
        assert "b" in order[:3] and "b" in order[6:9]

    def test_priority_classes_strict(self):
        q = FairQueue()
        q.push(_req(priority=1, arrival=0.0))
        q.push(_req(priority=0, arrival=9.0))   # later but more urgent
        assert q.pop().priority == 0
        assert q.pop().priority == 1

    def test_idle_reentry_no_banked_credit(self):
        """A tenant that idles re-enters at the active minimum — it cannot
        bank virtual time and then monopolize the queue."""
        q = FairQueue()
        for i in range(6):
            q.push(_req(tenant="busy", arrival=float(i), seed=i))
        for _ in range(4):
            q.pop()                       # busy's vt advances to 4
        q.push(_req(tenant="late", arrival=99.0))
        # late re-enters at busy's vt, not 0: service alternates instead of
        # late draining its whole backlog first
        got = [q.pop().tenant for _ in range(3)]
        assert got.count("late") == 1

    def test_len_iter_and_empty_pop(self):
        q = FairQueue()
        assert not q and len(q) == 0
        q.push(_req())
        assert len(list(iter(q))) == 1
        q.pop()
        with pytest.raises(IndexError):
            q.pop()


# --------------------------------------------------------------------------- #
# tenant accounting
# --------------------------------------------------------------------------- #
class TestTenantReport:
    def test_rejections_count_as_slo_misses(self):
        ok = _req(tenant="t", slo_ms=100.0)
        ok.done, ok.finished_at = True, 0.05
        shed = _req(tenant="t", slo_ms=100.0)
        shed.rejected, shed.finished_at = True, 0.0
        rep = tenant_report([ok, shed])["t"]
        assert rep["finished"] == 1 and rep["rejected"] == 1
        assert rep["slo_total"] == 2 and rep["slo_attained"] == 1
        assert rep["slo_attainment"] == 0.5

    def test_no_slo_attainment_is_one(self):
        r = _req(tenant="x")
        r.done, r.finished_at = True, 1.0
        assert tenant_report([r])["x"]["slo_attainment"] == 1.0


# --------------------------------------------------------------------------- #
# autoscaler policy
# --------------------------------------------------------------------------- #
class TestQueueAutoscaler:
    def test_eager_scale_up(self):
        a = QueueAutoscaler(slots_per_replica=4, min_replicas=1,
                            max_replicas=4)
        # deep queue → the whole fleet in one tick (ASHA-style backfill)
        assert a.tick(queued=100, busy=4, active=1) == 4
        assert a.events == [(0.0, "up", 4)]

    def test_scale_down_needs_hysteresis(self):
        a = QueueAutoscaler(slots_per_replica=4, min_replicas=1,
                            max_replicas=4, hysteresis=3)
        assert a.tick(queued=0, busy=1, active=2) == 2   # streak 1
        assert a.tick(queued=0, busy=1, active=2) == 2   # streak 2
        assert a.tick(queued=0, busy=1, active=2) == 1   # streak 3 → down
        assert a.events[-1] == (0.0, "down", 1)

    def test_busy_tick_resets_streak(self):
        a = QueueAutoscaler(slots_per_replica=4, min_replicas=1,
                            max_replicas=4, hysteresis=2)
        a.tick(queued=0, busy=0, active=2)               # streak 1
        a.tick(queued=8, busy=8, active=2)               # resets
        a.tick(queued=0, busy=0, active=2)               # streak 1 again
        assert a.tick(queued=0, busy=0, active=2) == 1   # streak 2 → down

    def test_bounds_and_validation(self):
        a = QueueAutoscaler(slots_per_replica=2, min_replicas=2,
                            max_replicas=3)
        assert a.tick(queued=1000, busy=6, active=3) == 3   # capped at max
        assert a.tick(queued=0, busy=0, active=1) == 2      # floored at min
        with pytest.raises(ValueError):
            QueueAutoscaler(slots_per_replica=2, min_replicas=3,
                            max_replicas=2)
        with pytest.raises(ValueError):
            QueueAutoscaler(slots_per_replica=0)


# --------------------------------------------------------------------------- #
# router integration (small real model)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_smoke("qwen2-1.5b")
    params, _ = init_model(KEY, cfg)
    return cfg, params


class TestReplicaRouter:
    def test_fleet_matches_single_slot_oracle(self, smoke_lm):
        """Greedy token streams from the fused-span fleet must equal the
        slot-at-a-time single engine, request for request."""
        cfg, params = smoke_lm
        mk = lambda: [_req(n=n, max_new=5, seed=i, vocab=cfg.vocab_size)
                      for i, n in enumerate((5, 9, 13, 7, 11, 6, 8, 10))]
        router = ReplicaRouter(cfg, params, slots_per_replica=2,
                               max_replicas=2, max_seq=64)
        served = mk()
        router.run(served)
        eng = ServeEngine(cfg, params, batch_size=1, max_seq=64)
        for got, req in zip(served, mk()):
            assert got.done
            assert got.out_tokens == eng._run_one(req).out_tokens

    def test_skewed_tenants_light_not_starved(self, smoke_lm):
        """16 heavy-tenant requests land with 4 light-tenant ones; fair
        queuing must interleave so the light tenant's mean latency beats
        the heavy tenant's (FIFO would finish light dead last)."""
        cfg, params = smoke_lm
        heavy = [_req(tenant="heavy", n=6, max_new=3, seed=i,
                      vocab=cfg.vocab_size) for i in range(16)]
        light = [_req(tenant="light", n=6, max_new=3, seed=100 + i,
                      vocab=cfg.vocab_size) for i in range(4)]
        router = ReplicaRouter(cfg, params, slots_per_replica=2,
                               max_replicas=1, max_seq=64)
        router.run(heavy + light, now_fn=_ticking_clock())
        rep = router.report()["tenants"]
        assert rep["light"]["finished"] == 4
        assert rep["light"]["latency_p50"] < rep["heavy"]["latency_p50"]

    def test_slo_rejection_accounting(self, smoke_lm):
        """With a warmed EMA predicting 10 s per generated token against a
        1 ms SLO, every SLO-carrying request is shed; no-SLO traffic still
        serves."""
        cfg, params = smoke_lm
        router = ReplicaRouter(cfg, params, slots_per_replica=2,
                               max_replicas=1, max_seq=64,
                               admission="reject")
        router._ema_tok = 10.0
        router._completions = 5
        doomed = [_req(tenant="slo", slo_ms=1.0, n=5, max_new=2, seed=i,
                       vocab=cfg.vocab_size) for i in range(3)]
        free = [_req(tenant="free", n=5, max_new=2, seed=10 + i,
                     vocab=cfg.vocab_size) for i in range(2)]
        router.run(doomed + free)
        rep = router.report()
        assert rep["rejected"] == 3
        assert all(r.rejected and not r.done for r in doomed)
        assert all(r.done for r in free)
        t = rep["tenants"]
        assert t["slo"]["slo_attainment"] == 0.0   # shed = missed
        assert t["free"]["finished"] == 2

    def test_degrade_halves_generation(self, smoke_lm):
        """degrade mode: a hopeless-at-full-length request is re-tested at
        half length instead of shed outright."""
        cfg, params = smoke_lm
        router = ReplicaRouter(cfg, params, slots_per_replica=2,
                               max_replicas=1, max_seq=64,
                               admission="degrade")
        router._ema_tok = 1.0
        router._completions = 5
        # full length predicts 8 + 0.1*5 = 8.5 s, half predicts 4.5 s —
        # a 7 s deadline lands between the two → degrade path
        req = _req(slo_ms=7000.0, n=5, max_new=8, vocab=cfg.vocab_size)
        router.run([req])
        assert req.degraded and req.done and not req.rejected
        assert len(req.out_tokens) == 4
        assert router.report()["degraded"] == 1

    def test_admission_scales_with_request_length(self, smoke_lm):
        """Regression: the pre-fix per-REQUEST EMA predicted the same
        completion time for a 4-token and a 40-token generation, so both
        were admitted or both shed.  Normalized per generated token, the
        long request must be rejected at the same queue state where the
        short one (same prompt, same SLO) is admitted."""
        cfg, params = smoke_lm
        router = ReplicaRouter(cfg, params, slots_per_replica=2,
                               max_replicas=1, max_seq=64,
                               admission="reject")
        router._ema_tok = 1.0
        router._completions = 5
        long_req = _req(tenant="long", slo_ms=10_000.0, n=5, max_new=40,
                        seed=0, vocab=cfg.vocab_size)
        short_req = _req(tenant="short", slo_ms=10_000.0, n=5, max_new=4,
                         seed=1, vocab=cfg.vocab_size)
        router.run([long_req, short_req])
        assert long_req.rejected and not long_req.done
        assert short_req.done and not short_req.rejected

    def test_autoscale_up_then_drain(self, smoke_lm):
        """A burst spins extra lane groups up; the drain after the burst
        deactivates them from the top with the span still contiguous."""
        cfg, params = smoke_lm
        auto = QueueAutoscaler(slots_per_replica=2, min_replicas=1,
                               max_replicas=3, hysteresis=1)
        router = ReplicaRouter(cfg, params, slots_per_replica=2,
                               max_replicas=3, min_replicas=1,
                               max_seq=64, autoscaler=auto)
        reqs = [_req(n=5, max_new=4, seed=i, vocab=cfg.vocab_size)
                for i in range(12)]
        router.run(reqs, now_fn=_ticking_clock())
        assert all(r.done for r in reqs)
        kinds = [k for _, k, _ in auto.events]
        assert "up" in kinds and "down" in kinds
        assert router.active < 3         # drained after the burst
        assert router.report()["finished"] == 12

    def test_warmup_precompiles_serving_shapes(self, smoke_lm):
        """PR-8 contract, asserted: after warmup a fixed fleet serves
        whole waves without a single jax compile — the retrace sentinel
        counts backend-compile events directly instead of inferring from
        the span-step cache keys."""
        cfg, params = smoke_lm
        router = ReplicaRouter(cfg, params, slots_per_replica=2,
                               max_replicas=2, max_seq=64)
        router.warmup(prompt_lens=[5, 9, 13])
        with assert_no_retrace("3-wave fleet serve after warmup"):
            for wave in range(3):
                reqs = [_req(n=n, max_new=3, seed=10 * wave + i,
                             vocab=cfg.vocab_size)
                        for i, n in enumerate((5, 9, 13))]
                router.run(reqs)
                assert all(r.done for r in reqs)

    def test_wave_bucket_ladder(self, smoke_lm):
        cfg, params = smoke_lm
        router = ReplicaRouter(cfg, params, slots_per_replica=4,
                               max_replicas=4, max_seq=64)
        assert [router._wave_bucket(n) for n in (1, 2, 3, 5, 9, 16, 99)] \
            == [1, 2, 4, 8, 16, 16, 16]


# --------------------------------------------------------------------------- #
# quantized decode parity
# --------------------------------------------------------------------------- #
class TestQuantizedDecode:
    """Tolerances documented in docs/benchmarks.md: on the random smoke
    model, quantized forward logits stay within 8 % (bf16) / 20 % (int8)
    of the fp32 logit range — measured ~3.4 % / ~11 %, pinned at ~2×
    margin.  Within the quantized path itself decode is exact: the fleet
    and the slot-at-a-time oracle emit identical streams."""

    @pytest.mark.parametrize("mode,rel_tol", [("bf16", 0.08), ("int8", 0.20)])
    def test_quantized_logits_within_tolerance(self, smoke_lm, mode, rel_tol):
        from repro.models.layers.quant import quantize_model_params
        from repro.models.transformer import TransformerLM

        cfg, params = smoke_lm
        model = TransformerLM(cfg)
        rng = np.random.default_rng(0)
        toks = np.asarray(rng.integers(0, cfg.vocab_size, size=(2, 16)),
                          np.int32)
        ref_logits, _ = model.forward(params, toks)
        got, _ = model.forward(quantize_model_params(params, mode), toks)
        ref_np = np.asarray(ref_logits, np.float32)
        err = np.abs(np.asarray(got, np.float32) - ref_np).max()
        assert err <= rel_tol * np.abs(ref_np).max()

    def test_int8_fleet_matches_int8_oracle(self, smoke_lm):
        """The quantized fleet is exactly self-consistent: int8 fused-span
        decode equals int8 slot-at-a-time decode, token for token."""
        import dataclasses

        cfg, params = smoke_lm
        qcfg = dataclasses.replace(cfg, quantize="int8")
        mk = lambda: [_req(n=n, max_new=4, seed=i, vocab=cfg.vocab_size)
                      for i, n in enumerate((5, 9, 7, 11))]
        router = ReplicaRouter(qcfg, params, slots_per_replica=2,
                               max_replicas=2, max_seq=64)
        served = mk()
        router.run(served)
        eng = ServeEngine(qcfg, params, batch_size=1, max_seq=64)
        for got, req in zip(served, mk()):
            assert got.out_tokens == eng._run_one(req).out_tokens
