"""Search determinism on a real 8-device mesh (ISSUE 3 acceptance).

One subprocess runs the same 8-config logreg grid (seeded) under every
combination of {3 collective schedules} x {stacked, sequential}; the host
then asserts:

  * **identical trial ordering** — every run enumerates the same configs
    in the same order (a pure function of the seed);
  * **identical best config** — exact equality across all six runs
    (fp tolerance on scores, exact on the choice);
  * **stacked == sequential** per schedule — scores and trained weights
    to fp tolerance;
  * **stacked == per-config single-model training** — each device-stacked
    trial's weights match `LogisticRegressionAlgorithm.train` of that
    config alone on the same train view, same mesh, same schedule (the
    grid-of-8 acceptance criterion).
"""
import numpy as np
import pytest

from conftest import result_json, run_devices_subprocess

pytestmark = pytest.mark.slow

_PROGRAM = """
import json
import numpy as np
import jax

from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm, LogisticRegressionParameters)
from repro.core.collectives import CollectiveSchedule
from repro.core.compat import make_mesh
from repro.core.numeric_table import MLNumericTable
from repro.tune import ModelSearch, fold_view, grid, holdout_split

assert len(jax.devices()) == 8, jax.devices()
mesh = make_mesh((8,), ("data",))

ROWS, D, EPOCHS = 128, 8, 3
rng = np.random.default_rng(42)
X = rng.normal(size=(ROWS, D)).astype(np.float32)
w = np.linspace(-1, 1, D).astype(np.float32)
y = (X @ w > 0).astype(np.float32)
table = MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                  mesh=mesh)

CONFIGS = grid({"learning_rate": [0.05, 0.1, 0.2, 0.3], "l2": [0.0, 0.01]})
assert len(CONFIGS) == 8

out = {"runs": {}, "solo": {}}
for sched in CollectiveSchedule:
    for mode in ("stacked", "sequential"):
        res = ModelSearch("logreg", CONFIGS, num_epochs=EPOCHS,
                          chunks_per_epoch=1, folds=None, val_fraction=0.25,
                          schedule=sched, execution=mode, seed=0).run(table)
        out["runs"][sched.value + "/" + mode] = {
            "order": [t.config for t in res.trials],
            "scores": [t.score for t in res.trials],
            "weights": [np.asarray(t.state).tolist() for t in res.trials],
            "best": res.best.config,
        }

# per-config single-model training on the identical train view
tr, _ = holdout_split(ROWS, 0.25, seed=0)
train_view = fold_view(table, tr)
for i, cfg in enumerate(CONFIGS):
    model = LogisticRegressionAlgorithm.train(
        train_view, LogisticRegressionParameters(
            max_iter=EPOCHS, schedule="allreduce", **cfg))
    out["solo"][str(i)] = np.asarray(model.weights).tolist()

# the PR-3 compile-once contract, asserted by the retrace sentinel
# instead of inferred from timings: after the first rung segment warms
# the stacked epoch, later segments (new start_epoch, flipped active
# mask, backfilled round offsets) reuse the SAME compiled epoch on the
# real 8-device mesh — zero jax compiles.
import jax.numpy as jnp
from repro.analysis import assert_no_retrace
from repro.core.optimizer import sgd_trial_round
from repro.core.runner import DistributedRunner

K = 4
runner = DistributedRunner(mesh=mesh, schedule="allreduce")
grad = lambda vec, w, hyper: (jax.nn.sigmoid(vec[1:] @ w) - vec[0]) * vec[1:]
step = sgd_trial_round(grad, local_batch_size=4)
hyper = {"lr": jnp.full((K,), 0.1, jnp.float32),
         "decay": jnp.ones((K,), jnp.float32),
         "l1": jnp.zeros((K,), jnp.float32)}
win = jnp.asarray(np.concatenate([y[:, None], X], 1))
stream = iter(lambda: {"data": win}, None)
trials = jnp.zeros((K, D), jnp.float32)

# masks/offsets are built (and their tiny host->device converts compiled)
# before the guard: the contract under test is the EPOCH staying warm
act2 = jnp.asarray([True, False, True, True])
act3 = jnp.asarray([True, False, False, True])
offs = jnp.asarray([0, 0, 0, 2], jnp.int32)
warm = runner.run_stacked_epochs(stream, trials, hyper, step, 1)
with assert_no_retrace("stacked rung segments after the first"):
    seg2 = runner.run_stacked_epochs(stream, warm, hyper, step, 2,
                                     start_epoch=1, active=act2)
    runner.run_stacked_epochs(stream, seg2, hyper, step, 3, start_epoch=2,
                              active=act3, round_offsets=offs)
out["segment_retraces"] = 0

print("RESULT::" + json.dumps(out))
"""


def test_search_deterministic_across_schedules_and_execution():
    out = result_json(run_devices_subprocess(_PROGRAM))
    runs = out["runs"]
    assert len(runs) == 6
    # the sentinel inside the subprocess raised (and the run died) if any
    # post-warmup rung segment recompiled; 0 here means it was reached
    assert out["segment_retraces"] == 0

    ref_key = "allreduce/stacked"
    ref = runs[ref_key]
    for key, run in runs.items():
        # identical trial ordering everywhere
        assert run["order"] == ref["order"], key
        # identical best config, exactly
        assert run["best"] == ref["best"], key

    # stacked == sequential per schedule: scores and weights to fp tolerance
    for sched in ("allreduce", "gather_broadcast", "reduce_scatter"):
        st, sq = runs[f"{sched}/stacked"], runs[f"{sched}/sequential"]
        np.testing.assert_allclose(st["scores"], sq["scores"], atol=1e-5,
                                   err_msg=sched)
        np.testing.assert_allclose(np.asarray(st["weights"]),
                                   np.asarray(sq["weights"]), atol=1e-5,
                                   err_msg=sched)

    # schedules agree with each other to fp tolerance
    for key, run in runs.items():
        np.testing.assert_allclose(run["scores"], ref["scores"], atol=1e-4,
                                   err_msg=key)

    # the acceptance grid: every device-stacked trial matches training
    # that config alone on the same 8-device mesh
    stacked_w = np.asarray(ref["weights"])
    for i in range(8):
        np.testing.assert_allclose(
            stacked_w[i], np.asarray(out["solo"][str(i)]), atol=1e-5,
            err_msg=f"stacked trial {i} diverged from single-model training")
