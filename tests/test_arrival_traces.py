"""Arrival-trace shapes for ``launch/serve.py`` (regression).

The ``burst`` kind used to place its second wave at ``0.5 / rate * n``
seconds — an offset that *grew with the trace length*, so large traces
degenerated into two disjoint static batches that never overlapped in the
slot table and inflated the continuous-batching backfill win.  The fix
pins the second wave at one mean inter-arrival gap (``1 / rate``),
independent of ``n``; these tests pin every kind's contract.
"""
import numpy as np
import pytest

from repro.launch.serve import arrival_trace

KINDS = ("none", "poisson", "uniform", "burst")


@pytest.mark.parametrize("kind", KINDS)
def test_trace_monotone_nonnegative(kind):
    t = arrival_trace(kind, 64, rate=100.0, seed=3)
    assert t.shape == (64,)
    assert np.all(t >= 0)
    assert np.all(np.diff(t) >= 0) or kind == "burst"  # burst sorted below
    assert np.all(np.sort(t) == np.sort(t))  # finite, comparable
    assert np.isfinite(t).all()


@pytest.mark.parametrize("kind", ("poisson", "uniform"))
def test_trace_mean_rate(kind):
    """Mean inter-arrival time ~ 1/rate (exact for uniform, statistical
    for poisson over a long trace)."""
    rate = 50.0
    n = 2000
    t = arrival_trace(kind, n, rate=rate, seed=0)
    mean_gap = t[-1] / (n - 1) if kind == "uniform" else t[-1] / n
    assert mean_gap == pytest.approx(1.0 / rate, rel=0.1)


def test_all_at_once_kinds():
    assert np.all(arrival_trace("none", 8, rate=100.0, seed=0) == 0.0)
    # rate <= 0 means "no pacing" for every kind
    assert np.all(arrival_trace("poisson", 8, rate=0.0, seed=0) == 0.0)


def test_burst_offset_is_n_independent():
    """The second wave lands at exactly 1/rate regardless of n — the old
    ``0.5 / rate * n`` offset scaled with the trace length."""
    rate = 10.0
    for n in (4, 40, 400):
        t = arrival_trace("burst", n, rate=rate, seed=0)
        half = (n + 1) // 2
        assert np.all(t[:half] == 0.0)
        assert np.all(t[half:] == 1.0 / rate)
    # waves must be close enough to overlap in a slot table: the gap is
    # one mean inter-arrival, not n/2 of them
    big = arrival_trace("burst", 1000, rate=10.0, seed=0)
    assert big.max() == pytest.approx(0.1)


def test_burst_splits_evenly():
    t = arrival_trace("burst", 7, rate=5.0, seed=0)
    assert (t == 0.0).sum() == 4 and (t > 0).sum() == 3


def test_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown arrival kind"):
        arrival_trace("thundering-herd", 4, rate=1.0, seed=0)


def test_poisson_deterministic_per_seed():
    a = arrival_trace("poisson", 32, rate=20.0, seed=7)
    b = arrival_trace("poisson", 32, rate=20.0, seed=7)
    c = arrival_trace("poisson", 32, rate=20.0, seed=8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
