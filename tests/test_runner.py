"""DistributedRunner: the shared execution layer (docs/architecture.md).

Covers the paper's §IV-A schedule-equivalence claim end to end: all three
CollectiveSchedules must produce identical models (to fp tolerance) for
logistic regression and k-means on a real multi-device CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), plus the
partition-layer round-trip property and the runner's emulated-mode
semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import partition as pt
from repro.core.collectives import CollectiveSchedule
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import DistributedRunner
from repro.data import BatchIterator

# --------------------------------------------------------------------------- #
# schedule agreement on a real 8-device mesh (paper §IV-A)
# --------------------------------------------------------------------------- #
_MESH_AGREEMENT_PROGRAM = """
import json
import numpy as np
import jax

from repro.core.compat import make_mesh
from repro.core import MLNumericTable, CollectiveSchedule, DistributedRunner
from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm, LogisticRegressionParameters)
from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.algorithms.als import (ALSParameters, BroadcastALS,
                                       pack_csr_table)
from repro.data import synth_classification, synth_netflix_tiled

assert len(jax.devices()) == 8, jax.devices()
mesh = make_mesh((8,), ("data",))

X, y, _ = synth_classification(512, 16, seed=0)
data = np.concatenate([y[:, None], X], 1).astype(np.float32)
table = MLNumericTable.from_numpy(data, mesh=mesh)
tX = MLNumericTable.from_numpy(X.astype(np.float32), mesh=mesh)

drift = {}
logreg, kmeans = {}, {}
for sched in CollectiveSchedule:
    p = LogisticRegressionParameters(learning_rate=0.5, max_iter=5,
                                     local_batch_size=16, schedule=sched)
    logreg[sched] = np.asarray(LogisticRegressionAlgorithm.train(table, p).weights)
    kp = KMeansParameters(k=4, max_iter=5, seed=0, schedule=sched)
    kmeans[sched] = np.asarray(KMeans.train(tX, kp).centroids)

# mesh-mode combine="concat": directly (identity map must reassemble the
# table on every schedule) and through ALS (whose factor broadcast rides it)
M = synth_netflix_tiled(users=64, items=48, rank=4, tiles=1, density=0.2)
r, c = np.nonzero(M)
v = M[r, c]
als = {}
for sched in CollectiveSchedule:
    runner = DistributedRunner.for_table(tX, schedule=sched)
    got = runner.partition_apply(tX.data, lambda b: b * 1.0, combine="concat")
    drift["concat_" + sched.value] = float(
        np.abs(np.asarray(got) - X.astype(np.float32)).max())
    d = pack_csr_table(r, c, v, M.shape[0], 32, mesh=mesh)
    dT = pack_csr_table(c, r, v, M.shape[1], 32, mesh=mesh)
    ap = ALSParameters(rank=4, lam=0.05, max_iter=3, seed=0, schedule=sched)
    als[sched] = np.asarray(BroadcastALS.train(d, ap, data_transposed=dT).U)

ref_w = logreg[CollectiveSchedule.ALLREDUCE]
ref_c = kmeans[CollectiveSchedule.ALLREDUCE]
ref_u = als[CollectiveSchedule.ALLREDUCE]
for sched in CollectiveSchedule:
    drift["logreg_" + sched.value] = float(np.abs(logreg[sched] - ref_w).max())
    drift["kmeans_" + sched.value] = float(np.abs(kmeans[sched] - ref_c).max())
    drift["als_" + sched.value] = float(np.abs(als[sched] - ref_u).max())
print("RESULT::" + json.dumps(drift))
"""


def test_schedules_agree_on_8_device_mesh(eight_device_run):
    """All three schedules must train identical logreg, kmeans, and ALS
    models on an 8-way data-parallel mesh — the runner makes the schedule a
    pure wire-pattern knob — and mesh-mode combine="concat" must reassemble
    partitioned rows exactly under every schedule."""
    drift = eight_device_run(_MESH_AGREEMENT_PROGRAM)
    for key, d in drift.items():
        assert d < 1e-5, f"{key}: schedules disagree by {d}"


# --------------------------------------------------------------------------- #
# emulated-mode semantics (always run, one device)
# --------------------------------------------------------------------------- #
class TestRunOnce:
    def test_sum_matches_numpy(self, rng):
        X = np.asarray(rng.normal(size=(32, 5)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)
        got = runner.run_once(t, lambda b: jnp.sum(b, axis=0), combine="sum")
        np.testing.assert_allclose(np.asarray(got), X.sum(0), rtol=1e-5)

    def test_mean_matches_numpy(self, rng):
        X = np.asarray(rng.normal(size=(32, 5)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)
        got = runner.run_once(t, lambda b: jnp.mean(b, axis=0), combine="mean")
        np.testing.assert_allclose(np.asarray(got), X.mean(0), rtol=1e-5)


class TestPartitionApply:
    def test_concat_is_identity_for_identity_fn(self, rng):
        X = np.asarray(rng.normal(size=(24, 3)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)
        got = runner.partition_apply(t.data, lambda b: b * 1.0, combine="concat")
        np.testing.assert_allclose(np.asarray(got), X, rtol=1e-6)

    def test_stacked_shape(self, rng):
        X = np.asarray(rng.normal(size=(24, 3)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)
        stacked = runner.partition_apply(t.data, lambda b: jnp.sum(b, 0)[None])
        assert stacked.shape == (4, 1, 3)

    def test_broadcast_args(self, rng):
        X = np.asarray(rng.normal(size=(16, 4)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=2)
        runner = DistributedRunner.for_table(t)
        w = jnp.ones((4,), jnp.float32)
        got = runner.partition_apply(t.data, lambda b, ww: b @ ww,
                                     broadcast=(w,), combine="concat")
        np.testing.assert_allclose(np.asarray(got), X.sum(1), rtol=1e-5)


class TestRunRounds:
    def test_full_batch_gd_matches_closed_loop(self, rng):
        """sum-combined gradient rounds == the same loop written by hand."""
        X = np.asarray(rng.normal(size=(32, 3)), np.float32)
        w_true = np.asarray(rng.normal(size=3), np.float32)
        y = X @ w_true
        data = np.concatenate([y[:, None], X], 1).astype(np.float32)
        t = MLNumericTable.from_numpy(data, num_shards=4)
        runner = DistributedRunner.for_table(t)
        lr = 0.01

        def local_grad(block, w, r):
            x, yy = block[:, 1:], block[:, 0]
            return jnp.sum(x * (x @ w - yy)[:, None], axis=0)

        got = runner.run_rounds(
            t, jnp.zeros(3, jnp.float32), local_grad, 20, combine="sum",
            update=lambda w, g, r: w - lr * g)

        w = np.zeros(3, np.float32)
        for _ in range(20):
            w = w - lr * (X.T @ (X @ w - y))
        np.testing.assert_allclose(np.asarray(got), w, rtol=1e-4, atol=1e-5)

    def test_shard_invariance(self, rng):
        """mean-combined rounds over equal partitions must not depend on the
        partition count when every partition computes the same statistic."""
        X = np.asarray(rng.normal(size=(32, 3)), np.float32)
        outs = []
        for shards in (1, 2, 8):
            t = MLNumericTable.from_numpy(X, num_shards=shards)
            runner = DistributedRunner.for_table(t)
            out = runner.run_rounds(
                t, jnp.zeros(3, jnp.float32),
                lambda b, s, r: s + jnp.mean(b, axis=0), 3, combine="mean")
            outs.append(np.asarray(out))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)

    def test_schedule_knob_accepts_strings(self, rng):
        X = np.asarray(rng.normal(size=(16, 2)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        for sched in ("allreduce", "gather_broadcast", "reduce_scatter"):
            runner = DistributedRunner.for_table(t, schedule=sched)
            assert runner.schedule is CollectiveSchedule.parse(sched)


# --------------------------------------------------------------------------- #
# streaming mode (emulated partitions; mesh + kill behavior is covered by
# tests/test_streaming_resume.py subprocesses)
# --------------------------------------------------------------------------- #
def _window_source(rows, cols, base_seed=7):
    def source(step):
        srng = np.random.default_rng(base_seed + step)
        return {"data": srng.normal(size=(rows, cols)).astype(np.float32)}
    return source


class TestRunEpochs:
    def test_constant_stream_matches_run_rounds(self, rng):
        """A stream that replays the resident table every epoch with one
        chunk per epoch is mathematically run_rounds — the streaming loop
        must reproduce it exactly."""
        X = np.asarray(rng.normal(size=(32, 3)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)

        def local_step(block, s, r):
            return s + jnp.mean(block, axis=0) / (1.0 + r)

        resident = runner.run_rounds(t, jnp.zeros(3), local_step, 5,
                                     combine="mean")
        stream = BatchIterator(lambda step: {"data": X})
        streamed = runner.run_epochs(stream, jnp.zeros(3), local_step, 5,
                                     combine="mean")
        np.testing.assert_array_equal(np.asarray(streamed),
                                      np.asarray(resident))
        assert stream.step == 5

    def test_chunks_split_the_window_in_order(self, rng):
        """With chunks_per_epoch=c, round r must see the window's (r%c)-th
        row chunk of every partition, in order: weight each round's
        contribution by its round index and compare to the same walk done
        in numpy."""
        X = np.asarray(rng.normal(size=(16, 2)), np.float32)
        runner = DistributedRunner(num_shards=2)
        stream = BatchIterator(lambda step: {"data": X})
        got = runner.run_epochs(
            stream, jnp.zeros(2),
            lambda b, s, r: s + (r + 1.0) * jnp.sum(b, axis=0), 1,
            combine="mean", chunks_per_epoch=4)
        # shards of 8 rows, chunks of 2 rows: round r sees rows
        # [shard*8 + 2r, shard*8 + 2r+2) of each shard
        shards = X.reshape(2, 8, 2)
        expect = np.zeros(2, np.float32)
        for r in range(4):
            chunk_sums = shards[:, 2 * r: 2 * r + 2].sum(axis=1)  # (2, 2)
            expect = expect + (r + 1.0) * chunk_sums.mean(axis=0)
        np.testing.assert_allclose(np.asarray(got), expect, rtol=1e-5)

    def test_checkpoint_cadence_and_metadata(self, tmp_ckpt_dir):
        from repro.checkpoint import latest_step, load_metadata
        from repro.core.runner import CheckpointPolicy

        runner = DistributedRunner(num_shards=2)
        stream = BatchIterator(_window_source(8, 2))
        runner.run_epochs(stream, jnp.zeros(2),
                          lambda b, s, r: s + jnp.mean(b, 0), 5,
                          combine="mean", chunks_per_epoch=2,
                          checkpoint=CheckpointPolicy(tmp_ckpt_dir,
                                                      every_epochs=2))
        # epochs 2, 4 on cadence plus the final state at 5
        assert latest_step(tmp_ckpt_dir) == 5
        meta = load_metadata(tmp_ckpt_dir, step=4)
        assert meta["epoch"] == 4 and meta["stream_step"] == 4
        assert meta["chunks_per_epoch"] == 2 and meta["num_shards"] == 2
        assert meta["schedule"] == "allreduce"

    def test_resume_rejects_mismatched_layout(self, tmp_ckpt_dir):
        from repro.core.runner import CheckpointPolicy

        step = lambda b, s, r: s + jnp.mean(b, 0)
        runner = DistributedRunner(num_shards=2)
        runner.run_epochs(BatchIterator(_window_source(8, 2)), jnp.zeros(2),
                          step, 2, checkpoint=CheckpointPolicy(tmp_ckpt_dir))
        with pytest.raises(ValueError, match="num_shards"):
            DistributedRunner(num_shards=4).resume(
                tmp_ckpt_dir, BatchIterator(_window_source(8, 2)),
                jnp.zeros(2), step, 4)
        with pytest.raises(ValueError, match="schedule"):
            DistributedRunner(num_shards=2, schedule="reduce_scatter").resume(
                tmp_ckpt_dir, BatchIterator(_window_source(8, 2)),
                jnp.zeros(2), step, 4)
        with pytest.raises(ValueError, match="chunks_per_epoch"):
            runner.resume(tmp_ckpt_dir, BatchIterator(_window_source(8, 2)),
                          jnp.zeros(2), step, 4, chunks_per_epoch=8)

    def test_resume_past_target_returns_snapshot(self, tmp_ckpt_dir):
        from repro.core.runner import CheckpointPolicy

        step = lambda b, s, r: s + jnp.mean(b, 0)
        runner = DistributedRunner(num_shards=2)
        final = runner.run_epochs(BatchIterator(_window_source(8, 2)),
                                  jnp.zeros(2), step, 3,
                                  checkpoint=CheckpointPolicy(tmp_ckpt_dir))
        again = runner.resume(tmp_ckpt_dir, BatchIterator(_window_source(8, 2)),
                              jnp.zeros(2), step, 3)
        np.testing.assert_array_equal(np.asarray(again), np.asarray(final))

    def test_apply_stream_forwards_chunk_mismatch_on_resume(self, tmp_ckpt_dir):
        """The high-level streaming APIs must surface the checkpoint's
        chunk-layout cross-check, not swallow the caller's value."""
        from repro.core.optimizer import MinibatchSGD, MinibatchSGDParameters
        from repro.core.runner import CheckpointPolicy

        p = MinibatchSGDParameters(
            w_init=jnp.zeros(2),
            grad=lambda vec, w: vec[1:] * (jnp.dot(vec[1:], w) - vec[0]))
        opt = MinibatchSGD(p)
        ck = CheckpointPolicy(tmp_ckpt_dir)
        opt.apply_stream(BatchIterator(_window_source(8, 3)), 2, num_shards=2,
                         chunks_per_epoch=2, checkpoint=ck)
        with pytest.raises(ValueError, match="chunks_per_epoch"):
            opt.apply_stream(BatchIterator(_window_source(8, 3)), 4,
                             num_shards=2, chunks_per_epoch=4, checkpoint=ck,
                             resume=True)
        # omitting the value inherits the checkpointed layout
        opt.apply_stream(BatchIterator(_window_source(8, 3)), 4, num_shards=2,
                         checkpoint=ck, resume=True)

    def test_rejects_bad_windows(self):
        runner = DistributedRunner(num_shards=4)
        step = lambda b, s, r: s
        with pytest.raises(ValueError, match="divide"):
            runner.run_epochs(BatchIterator(_window_source(10, 2)),
                              jnp.zeros(2), step, 1)
        with pytest.raises(ValueError, match="chunks_per_epoch"):
            runner.run_epochs(BatchIterator(_window_source(16, 2)),
                              jnp.zeros(2), step, 1, chunks_per_epoch=3)


# --------------------------------------------------------------------------- #
# partition layer round-trip (property)
# --------------------------------------------------------------------------- #
@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 64), cols=st.integers(1, 8),
       shards=st.sampled_from([1, 2, 3, 4, 8]), seed=st.integers(0, 2**16))
def test_partition_roundtrip_property(rows, cols, shards, seed):
    """pad → partition → unpartition → trim recovers any array exactly."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    padded, n_pad = pt.pad_rows(X, shards)
    assert padded.shape[0] % shards == 0
    assert n_pad == (-rows) % shards
    blocks = pt.partition_rows(padded, shards)
    assert blocks.shape == (shards, padded.shape[0] // shards, cols)
    back = pt.unpartition_rows(blocks)[:rows]
    np.testing.assert_array_equal(np.asarray(back), np.asarray(X))


def test_partition_rejects_indivisible():
    with pytest.raises(ValueError):
        pt.partition_rows(jnp.zeros((10, 2)), 3)


def test_runner_matches_table_layout(rng):
    X = np.asarray(rng.normal(size=(16, 2)), np.float32)
    t = MLNumericTable.from_numpy(X, num_shards=4)
    runner = DistributedRunner.for_table(t)
    assert runner.mesh is None and runner.num_shards == 4
