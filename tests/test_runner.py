"""DistributedRunner: the shared execution layer (docs/architecture.md).

Covers the paper's §IV-A schedule-equivalence claim end to end: all three
CollectiveSchedules must produce identical models (to fp tolerance) for
logistic regression and k-means on a real multi-device CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), plus the
partition-layer round-trip property and the runner's emulated-mode
semantics."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import partition as pt
from repro.core.collectives import CollectiveSchedule
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import DistributedRunner

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# --------------------------------------------------------------------------- #
# schedule agreement on a real 8-device mesh (paper §IV-A)
# --------------------------------------------------------------------------- #
_MESH_AGREEMENT_PROGRAM = """
import json
import numpy as np
import jax

from repro.core.compat import make_mesh
from repro.core import MLNumericTable, CollectiveSchedule, DistributedRunner
from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm, LogisticRegressionParameters)
from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.algorithms.als import (ALSParameters, BroadcastALS,
                                       pack_csr_table)
from repro.data import synth_classification, synth_netflix_tiled

assert len(jax.devices()) == 8, jax.devices()
mesh = make_mesh((8,), ("data",))

X, y, _ = synth_classification(512, 16, seed=0)
data = np.concatenate([y[:, None], X], 1).astype(np.float32)
table = MLNumericTable.from_numpy(data, mesh=mesh)
tX = MLNumericTable.from_numpy(X.astype(np.float32), mesh=mesh)

drift = {}
logreg, kmeans = {}, {}
for sched in CollectiveSchedule:
    p = LogisticRegressionParameters(learning_rate=0.5, max_iter=5,
                                     local_batch_size=16, schedule=sched)
    logreg[sched] = np.asarray(LogisticRegressionAlgorithm.train(table, p).weights)
    kp = KMeansParameters(k=4, max_iter=5, seed=0, schedule=sched)
    kmeans[sched] = np.asarray(KMeans.train(tX, kp).centroids)

# mesh-mode combine="concat": directly (identity map must reassemble the
# table on every schedule) and through ALS (whose factor broadcast rides it)
M = synth_netflix_tiled(users=64, items=48, rank=4, tiles=1, density=0.2)
r, c = np.nonzero(M)
v = M[r, c]
als = {}
for sched in CollectiveSchedule:
    runner = DistributedRunner.for_table(tX, schedule=sched)
    got = runner.partition_apply(tX.data, lambda b: b * 1.0, combine="concat")
    drift["concat_" + sched.value] = float(
        np.abs(np.asarray(got) - X.astype(np.float32)).max())
    d = pack_csr_table(r, c, v, M.shape[0], 32, mesh=mesh)
    dT = pack_csr_table(c, r, v, M.shape[1], 32, mesh=mesh)
    ap = ALSParameters(rank=4, lam=0.05, max_iter=3, seed=0, schedule=sched)
    als[sched] = np.asarray(BroadcastALS.train(d, ap, data_transposed=dT).U)

ref_w = logreg[CollectiveSchedule.ALLREDUCE]
ref_c = kmeans[CollectiveSchedule.ALLREDUCE]
ref_u = als[CollectiveSchedule.ALLREDUCE]
for sched in CollectiveSchedule:
    drift["logreg_" + sched.value] = float(np.abs(logreg[sched] - ref_w).max())
    drift["kmeans_" + sched.value] = float(np.abs(kmeans[sched] - ref_c).max())
    drift["als_" + sched.value] = float(np.abs(als[sched] - ref_u).max())
print("RESULT::" + json.dumps(drift))
"""


def test_schedules_agree_on_8_device_mesh():
    """All three schedules must train identical logreg, kmeans, and ALS
    models on an 8-way data-parallel mesh — the runner makes the schedule a
    pure wire-pattern knob — and mesh-mode combine="concat" must reassemble
    partitioned rows exactly under every schedule."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", _MESH_AGREEMENT_PROGRAM],
                         capture_output=True, text=True, env=env,
                         timeout=540, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    drift = json.loads(line[len("RESULT::"):])
    for key, d in drift.items():
        assert d < 1e-5, f"{key}: schedules disagree by {d}"


# --------------------------------------------------------------------------- #
# emulated-mode semantics (always run, one device)
# --------------------------------------------------------------------------- #
class TestRunOnce:
    def test_sum_matches_numpy(self, rng):
        X = np.asarray(rng.normal(size=(32, 5)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)
        got = runner.run_once(t, lambda b: jnp.sum(b, axis=0), combine="sum")
        np.testing.assert_allclose(np.asarray(got), X.sum(0), rtol=1e-5)

    def test_mean_matches_numpy(self, rng):
        X = np.asarray(rng.normal(size=(32, 5)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)
        got = runner.run_once(t, lambda b: jnp.mean(b, axis=0), combine="mean")
        np.testing.assert_allclose(np.asarray(got), X.mean(0), rtol=1e-5)


class TestPartitionApply:
    def test_concat_is_identity_for_identity_fn(self, rng):
        X = np.asarray(rng.normal(size=(24, 3)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)
        got = runner.partition_apply(t.data, lambda b: b * 1.0, combine="concat")
        np.testing.assert_allclose(np.asarray(got), X, rtol=1e-6)

    def test_stacked_shape(self, rng):
        X = np.asarray(rng.normal(size=(24, 3)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        runner = DistributedRunner.for_table(t)
        stacked = runner.partition_apply(t.data, lambda b: jnp.sum(b, 0)[None])
        assert stacked.shape == (4, 1, 3)

    def test_broadcast_args(self, rng):
        X = np.asarray(rng.normal(size=(16, 4)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=2)
        runner = DistributedRunner.for_table(t)
        w = jnp.ones((4,), jnp.float32)
        got = runner.partition_apply(t.data, lambda b, ww: b @ ww,
                                     broadcast=(w,), combine="concat")
        np.testing.assert_allclose(np.asarray(got), X.sum(1), rtol=1e-5)


class TestRunRounds:
    def test_full_batch_gd_matches_closed_loop(self, rng):
        """sum-combined gradient rounds == the same loop written by hand."""
        X = np.asarray(rng.normal(size=(32, 3)), np.float32)
        w_true = np.asarray(rng.normal(size=3), np.float32)
        y = X @ w_true
        data = np.concatenate([y[:, None], X], 1).astype(np.float32)
        t = MLNumericTable.from_numpy(data, num_shards=4)
        runner = DistributedRunner.for_table(t)
        lr = 0.01

        def local_grad(block, w, r):
            x, yy = block[:, 1:], block[:, 0]
            return jnp.sum(x * (x @ w - yy)[:, None], axis=0)

        got = runner.run_rounds(
            t, jnp.zeros(3, jnp.float32), local_grad, 20, combine="sum",
            update=lambda w, g, r: w - lr * g)

        w = np.zeros(3, np.float32)
        for _ in range(20):
            w = w - lr * (X.T @ (X @ w - y))
        np.testing.assert_allclose(np.asarray(got), w, rtol=1e-4, atol=1e-5)

    def test_shard_invariance(self, rng):
        """mean-combined rounds over equal partitions must not depend on the
        partition count when every partition computes the same statistic."""
        X = np.asarray(rng.normal(size=(32, 3)), np.float32)
        outs = []
        for shards in (1, 2, 8):
            t = MLNumericTable.from_numpy(X, num_shards=shards)
            runner = DistributedRunner.for_table(t)
            out = runner.run_rounds(
                t, jnp.zeros(3, jnp.float32),
                lambda b, s, r: s + jnp.mean(b, axis=0), 3, combine="mean")
            outs.append(np.asarray(out))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)

    def test_schedule_knob_accepts_strings(self, rng):
        X = np.asarray(rng.normal(size=(16, 2)), np.float32)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        for sched in ("allreduce", "gather_broadcast", "reduce_scatter"):
            runner = DistributedRunner.for_table(t, schedule=sched)
            assert runner.schedule is CollectiveSchedule.parse(sched)


# --------------------------------------------------------------------------- #
# partition layer round-trip (property)
# --------------------------------------------------------------------------- #
@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 64), cols=st.integers(1, 8),
       shards=st.sampled_from([1, 2, 3, 4, 8]), seed=st.integers(0, 2**16))
def test_partition_roundtrip_property(rows, cols, shards, seed):
    """pad → partition → unpartition → trim recovers any array exactly."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    padded, n_pad = pt.pad_rows(X, shards)
    assert padded.shape[0] % shards == 0
    assert n_pad == (-rows) % shards
    blocks = pt.partition_rows(padded, shards)
    assert blocks.shape == (shards, padded.shape[0] // shards, cols)
    back = pt.unpartition_rows(blocks)[:rows]
    np.testing.assert_array_equal(np.asarray(back), np.asarray(X))


def test_partition_rejects_indivisible():
    with pytest.raises(ValueError):
        pt.partition_rows(jnp.zeros((10, 2)), 3)


def test_runner_matches_table_layout(rng):
    X = np.asarray(rng.normal(size=(16, 2)), np.float32)
    t = MLNumericTable.from_numpy(X, num_shards=4)
    runner = DistributedRunner.for_table(t)
    assert runner.mesh is None and runner.num_shards == 4
