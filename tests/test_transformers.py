"""Fitted-transformer contract: label safety (the seed-era standardize
scaled the label column), train/test leakage (corpus statistics fit on the
train view only, replayed on validation), and the replay properties the
pipeline rides on — row-by-row == whole-table, shard-layout invariance,
resident == streamed-chunk agreement, and value/dtype-exact checkpoint
round trips."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.mltable import MLTable
from repro.core.numeric_table import MLNumericTable
from repro.features import (
    BiasAdder,
    HashingVectorizer,
    NGrams,
    Standardizer,
    TfIdf,
    standardize,
)

DOCS = ["alpha beta alpha gamma", "beta gamma delta", "alpha delta delta",
        "gamma gamma beta alpha", "delta alpha beta", "beta beta gamma",
        "alpha gamma delta beta", "delta gamma alpha alpha"]


def _labeled_table(rng, n=32, d=4):
    X = np.asarray(rng.normal(3.0, 2.0, size=(n, d)), np.float32)
    y = np.asarray(rng.integers(0, 2, size=n), np.float32)
    data = np.concatenate([y[:, None], X], 1)
    names = ["label"] + [f"f{i}" for i in range(d)]
    return MLNumericTable.from_numpy(data, num_shards=4, names=names), y


class TestStandardizerLabelSafety:
    """Satellite: the Standardizer (and the shimmed function) must skip
    label/bias columns by default."""

    def test_label_column_passes_through_unchanged(self, rng):
        t, y = _labeled_table(rng)
        out = Standardizer().fit(t).transform(t)
        got = np.asarray(out.data)
        np.testing.assert_array_equal(got[:, 0], y)          # bit-exact
        # the feature columns DID standardize
        np.testing.assert_allclose(got[:, 1:].mean(0), 0.0, atol=1e-4)

    def test_shimmed_function_skips_label_by_default(self, rng):
        t, y = _labeled_table(rng)
        out = standardize(t)
        np.testing.assert_array_equal(np.asarray(out.data)[:, 0], y)

    def test_bias_column_passes_through(self, rng):
        t, _ = _labeled_table(rng)
        with_bias = BiasAdder().fit(t).transform(t)
        assert with_bias.names[1] == "bias"
        out = Standardizer().fit(with_bias).transform(with_bias)
        np.testing.assert_array_equal(np.asarray(out.data)[:, 1], 1.0)

    def test_constant_column_passes_through_even_unnamed(self, rng):
        X = np.asarray(rng.normal(size=(16, 3)), np.float32)
        X[:, 1] = 7.0
        t = MLNumericTable.from_numpy(X, num_shards=2)       # no names
        out = np.asarray(Standardizer().fit(t).transform(t).data)
        np.testing.assert_array_equal(out[:, 1], 7.0)

    def test_pipeline_supervised_skip_without_names(self, rng):
        """An unnamed supervised table still protects column 0 via the
        pipeline's default_skip."""
        t, y = _labeled_table(rng)
        unnamed = MLNumericTable.from_numpy(np.asarray(t.data), num_shards=4)
        out = Standardizer().fit(unnamed, default_skip=(0,)).transform(unnamed)
        np.testing.assert_array_equal(np.asarray(out.data)[:, 0], y)


class TestLeakage:
    """Satellite: corpus statistics fit on the train view only — a
    transformer fit on train folds produces identical vocab/IDF when
    transforming validation rows."""

    def test_ngram_vocab_fits_on_train_only(self):
        train = MLTable.from_text(DOCS[:5], num_partitions=2)
        val = MLTable.from_text(["epsilon epsilon zeta", DOCS[0]],
                                num_partitions=1)
        fitted = NGrams(n=1, top=16).fit(train)
        vocab_before = list(fitted.vocab)
        out = fitted.transform(val)
        assert list(fitted.vocab) == vocab_before     # no refit on val
        # the unseen word maps to NOTHING (no leak of val statistics)
        assert "epsilon" not in fitted.vocab
        first = np.asarray(out.to_numeric(1).data)[0]
        assert first.sum() == 0.0

    def test_idf_identical_transforming_validation(self, rng):
        train = MLTable.from_text(DOCS[:4], num_partitions=2)
        val = MLTable.from_text(DOCS[4:], num_partitions=1)
        ng = NGrams(n=1, top=8).fit(train)
        tf = TfIdf().fit(ng.transform(train).to_numeric(2))
        idf_before = np.asarray(tf.idf)
        tf.transform(ng.transform(val).to_numeric(1))
        np.testing.assert_array_equal(idf_before, np.asarray(tf.idf))

    def test_seed_function_refit_trap_is_closed(self):
        """The one-shot n_grams refit its vocabulary per call; the fitted
        class replays one vocabulary, so train and val featurize into the
        SAME feature space."""
        train = MLTable.from_text(DOCS[:5], num_partitions=2)
        val = MLTable.from_text(DOCS[5:], num_partitions=1)
        fitted = NGrams(n=1, top=8).fit(train)
        a = fitted.transform(train)
        b = fitted.transform(val)
        assert [c.name for c in a.schema.columns] == \
               [c.name for c in b.schema.columns]


class TestReplayProperties:
    """Satellite (hypothesis): fit on a table then transform row-by-row
    equals transform of the whole table; shard layout and streamed
    chunking don't change the result."""

    @settings(max_examples=8, deadline=None)
    @given(split=st.integers(1, 7))
    def test_rowwise_equals_whole_table_host(self, split):
        fitted = NGrams(n=1, top=8).fit(
            MLTable.from_text(DOCS, num_partitions=2))
        whole = fitted.transform_rows(DOCS)
        parts = np.concatenate([fitted.transform_rows(DOCS[:split]),
                                fitted.transform_rows(DOCS[split:])])
        np.testing.assert_array_equal(whole, parts)

    @settings(max_examples=8, deadline=None)
    @given(chunk=st.integers(1, 8), shards=st.sampled_from([1, 2, 4]))
    def test_device_apply_resident_equals_stream(self, chunk, shards):
        rng = np.random.default_rng(0)
        t, _ = _labeled_table(rng)
        t = MLNumericTable.from_numpy(np.asarray(t.data), num_shards=shards,
                                      names=t.names)
        fitted = Standardizer().fit(t)
        F = np.asarray(t.data)[:, 1:]                     # label-free rows
        whole = np.asarray(fitted.apply(F))
        chunks = [np.asarray(fitted.apply(F[i:i + chunk]))
                  for i in range(0, F.shape[0], chunk)]
        np.testing.assert_array_equal(whole, np.concatenate(chunks))

    @settings(max_examples=6, deadline=None)
    @given(shards=st.sampled_from([1, 2, 4]))
    def test_fit_is_shard_layout_invariant(self, shards):
        rng = np.random.default_rng(1)
        t, _ = _labeled_table(rng)
        data = np.asarray(t.data)
        base = Standardizer().fit(
            MLNumericTable.from_numpy(data, num_shards=1, names=t.names))
        other = Standardizer().fit(
            MLNumericTable.from_numpy(data, num_shards=shards, names=t.names))
        np.testing.assert_allclose(np.asarray(base.scale),
                                   np.asarray(other.scale),
                                   rtol=1e-6, atol=1e-7)

    def test_table_transform_agrees_with_apply(self, rng):
        """The table-tier transform and the serving-tier apply are the
        same map: table transform of the feature columns == apply on the
        label-free rows."""
        t, _ = _labeled_table(rng)
        ng = NGrams(n=1, top=8).fit(MLTable.from_text(DOCS, num_partitions=2))
        counts = ng.transform(MLTable.from_text(DOCS, num_partitions=2))
        ct = counts.to_numeric(2)
        tf = TfIdf().fit(ct)
        table_out = np.asarray(tf.transform(ct).data)
        row_out = np.asarray(tf.apply(np.asarray(ct.data)))
        np.testing.assert_allclose(table_out, row_out, rtol=1e-6, atol=1e-7)

    def test_hashing_is_process_stable(self):
        """The hashing vectorizer uses a stable CRC, so a restored
        transformer replays identically in a fresh interpreter."""
        import subprocess
        import sys

        f = HashingVectorizer(num_features=32, n=1).fit(
            MLTable.from_text(DOCS, num_partitions=1))
        here = f.transform_rows(DOCS[:2])
        prog = (
            "import numpy as np\n"
            "from repro.core.mltable import MLTable\n"
            "from repro.features import HashingVectorizer\n"
            f"docs = {DOCS[:2]!r}\n"
            "f = HashingVectorizer(num_features=32, n=1).fit(\n"
            "    MLTable.from_text(docs, num_partitions=1))\n"
            "print(repr(f.transform_rows(docs).tolist()))\n"
        )
        out = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True,
                             env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                  "PYTHONHASHSEED": "12345"},
                             cwd=__file__.rsplit("/tests/", 1)[0])
        assert out.returncode == 0, out.stderr[-2000:]
        other = np.asarray(eval(out.stdout.strip()), np.float32)
        np.testing.assert_array_equal(here, other)


class TestCheckpointRoundTrip:
    """Satellite (hypothesis): round-trip through checkpoint save/restore
    is value- and dtype-exact."""

    def test_transformer_partial_round_trip(self, rng, tmp_ckpt_dir):
        from repro.checkpoint import load_artifact, save_artifact

        t, _ = _labeled_table(rng)
        fitted = Standardizer().fit(t)
        save_artifact(tmp_ckpt_dir, fitted.partial)
        template = type(fitted).partial_template(fitted.host_state())
        restored, _ = load_artifact(tmp_ckpt_dir, template)
        for k in fitted.partial:
            a, b = np.asarray(fitted.partial[k]), np.asarray(restored[k])
            np.testing.assert_array_equal(a, b)
            assert a.dtype == b.dtype

    def test_fitted_pipeline_artifact_round_trip(self, rng, tmp_ckpt_dir):
        from repro.core.algorithms.logistic_regression import \
            LogisticRegressionAlgorithm
        from repro.pipeline import Pipeline

        rows = [(float(i % 2), DOCS[i % len(DOCS)]) for i in range(32)]
        raw = MLTable.from_rows(rows, names=["label", "text"],
                                num_partitions=4)

        def make():
            return Pipeline([NGrams(n=1, top=8, column="text"), TfIdf(),
                             Standardizer(),
                             LogisticRegressionAlgorithm(max_iter=4)],
                            num_shards=4)

        fitted = make().fit(raw)
        fitted.save(tmp_ckpt_dir)
        loaded = make().load(tmp_ckpt_dir)
        assert loaded["ngrams"].vocab == fitted["ngrams"].vocab
        for k in fitted.model.partial:
            a = np.asarray(fitted.model.partial[k])
            b = np.asarray(loaded.model.partial[k])
            np.testing.assert_array_equal(a, b)
            assert a.dtype == b.dtype
        texts = [t for _, t in rows[:4]]
        np.testing.assert_array_equal(np.asarray(fitted.predict(texts)),
                                      np.asarray(loaded.predict(texts)))

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 50))
    def test_tfidf_round_trip_values_exact(self, seed, tmp_path):
        from repro.checkpoint import load_artifact, save_artifact

        rng = np.random.default_rng(seed)
        counts = np.asarray(rng.integers(0, 5, size=(16, 6)), np.float32)
        t = MLNumericTable.from_numpy(counts, num_shards=2)
        fitted = TfIdf(skip=None).fit(t)
        d = str(tmp_path / f"ck{seed}")
        save_artifact(d, fitted.partial)
        restored, _ = load_artifact(
            d, type(fitted).partial_template(fitted.host_state()))
        np.testing.assert_array_equal(np.asarray(fitted.idf),
                                      np.asarray(restored["idf"]))
        rebuilt = type(fitted).from_state(fitted.host_state(), restored)
        np.testing.assert_array_equal(
            np.asarray(fitted.apply(counts)),
            np.asarray(rebuilt.apply(counts)))
