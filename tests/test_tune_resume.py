"""Kill-and-resume for a mid-search checkpoint, through the real CLI
(extends the `test_streaming_resume` subprocess fixtures).

Three `launch/tune.py` subprocesses over the identical seeded search:

  1. **straight** — the full 6-trial grid, no checkpointing;
  2. **killed** — same search with `--ckpt-dir`, fault-injected via
     `--kill-after-trial 3`: the process SIGKILLs itself right after
     trial 3's snapshot is published (an uncatchable preemption);
  3. **resumed** — same command line plus `--resume`: restores the three
     completed trials from the snapshot and runs only the rest.

The resumed search must match the uninterrupted one **trial-for-trial**:
same configs in the same order, fp-equal scores and trained weights, and
the same winner.
"""
import signal

import numpy as np
import pytest

from conftest import describe_failure, result_json, run_devices_subprocess

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(not hasattr(signal, "SIGKILL"),
                       reason="POSIX-only kill semantics"),
]

_GRID = "learning_rate=0.05,0.1,0.3;l2=0.0,0.01"
_COMMON = (f"--algorithm logreg --grid {_GRID} --rows 64 --features 6 "
           "--epochs 3 --chunks-per-epoch 2 --folds 2 --exec sequential "
           "--seed 0 --json")

_PROG = """
import repro.launch.tune as tune
tune.main({args!r}.split())
"""


def _run(args: str, devices: int = 4, check: bool = True):
    return run_devices_subprocess(_PROG.format(args=args), devices=devices,
                                  check=check)


def test_tune_cli_kill_and_resume_matches_uninterrupted(tmp_path):
    straight = result_json(_run(_COMMON))
    assert len(straight["trials"]) == 6

    ckpt = tmp_path / "search-ckpt"
    killed = _run(f"{_COMMON} --ckpt-dir {ckpt} --kill-after-trial 3",
                  check=False)
    assert killed.returncode == -signal.SIGKILL, describe_failure(killed)
    # the snapshot for three completed trials is on disk
    assert (ckpt / "step_3.npz").exists()

    resumed_proc = _run(f"{_COMMON} --ckpt-dir {ckpt} --resume")
    assert "resuming from unit 3" in resumed_proc.stdout
    resumed = result_json(resumed_proc)

    assert len(resumed["trials"]) == 6
    for want, got in zip(straight["trials"], resumed["trials"]):
        assert got["config"] == want["config"]
        assert got["score"] == pytest.approx(want["score"], abs=1e-6)
        np.testing.assert_allclose(
            np.asarray(got["state"]), np.asarray(want["state"]), atol=1e-6,
            err_msg=f"trial {want['index']} diverged after resume")
    assert resumed["best"]["config"] == straight["best"]["config"]
    assert resumed["best"]["index"] == straight["best"]["index"]


def test_tune_cli_resume_without_checkpoint_starts_fresh(tmp_path):
    out = _run(f"{_COMMON} --ckpt-dir {tmp_path / 'empty'} --resume",
               devices=1)
    assert "no checkpoint found; starting fresh" in out.stdout
    assert len(result_json(out)["trials"]) == 6
