"""LocalMatrix (paper Fig. A3): MATLAB-style partition-local linalg."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.local_matrix import LocalMatrix


def _mat(rng, m, n):
    return LocalMatrix(jnp.asarray(rng.normal(size=(m, n)), jnp.float32))


class TestShapes:
    def test_dims(self, rng):
        a = _mat(rng, 3, 4)
        assert a.dims == (3, 4) and a.num_rows == 3 and a.num_cols == 4

    def test_1d_promotes_to_column(self):
        a = LocalMatrix(jnp.arange(4.0))
        assert a.shape == (4, 1)

    def test_3d_rejected(self):
        with pytest.raises(ValueError):
            LocalMatrix(jnp.zeros((2, 2, 2)))


class TestComposition:
    def test_on_stacks_rows(self, rng):
        a, b = _mat(rng, 2, 3), _mat(rng, 4, 3)
        assert a.on(b).dims == (6, 3)

    def test_then_stacks_cols(self, rng):
        a, b = _mat(rng, 2, 3), _mat(rng, 2, 5)
        assert a.then(b).dims == (2, 8)


class TestArithmeticAndLinalg:
    def test_elementwise_matches_numpy(self, rng):
        a, b = _mat(rng, 3, 3), _mat(rng, 3, 3)
        np.testing.assert_allclose((a + b).data, np.asarray(a.data) + np.asarray(b.data), rtol=1e-6)
        np.testing.assert_allclose((a - 5).data, np.asarray(a.data) - 5, rtol=1e-6)
        np.testing.assert_allclose((a * b).data, np.asarray(a.data) * np.asarray(b.data), rtol=1e-6)

    def test_times_is_matmul(self, rng):
        a, b = _mat(rng, 3, 4), _mat(rng, 4, 2)
        np.testing.assert_allclose(a.times(b).data,
                                   np.asarray(a.data) @ np.asarray(b.data), rtol=1e-5)

    def test_dot_is_scalar_inner_product(self, rng):
        a = LocalMatrix(jnp.asarray(rng.normal(size=(4,)), jnp.float32))
        b = LocalMatrix(jnp.asarray(rng.normal(size=(4,)), jnp.float32))
        expect = float(np.asarray(a.data).ravel() @ np.asarray(b.data).ravel())
        assert abs(float(a.dot(b)) - expect) < 1e-5

    def test_solve(self, rng):
        A = np.asarray(rng.normal(size=(4, 4)), np.float32)
        A = A @ A.T + 4 * np.eye(4, dtype=np.float32)  # SPD
        x = np.asarray(rng.normal(size=(4, 1)), np.float32)
        b = A @ x
        got = LocalMatrix(jnp.asarray(A)).solve(jnp.asarray(b))
        np.testing.assert_allclose(got.data, x, rtol=1e-3, atol=1e-4)

    def test_transpose_inverse(self, rng):
        A = _mat(rng, 3, 3)
        np.testing.assert_allclose(A.T.data, np.asarray(A.data).T)
        Ainv = (A.times(A.T) + LocalMatrix(jnp.eye(3))).inverse()
        prod = Ainv.times(A.times(A.T) + LocalMatrix(jnp.eye(3)))
        np.testing.assert_allclose(prod.data, np.eye(3), atol=1e-4)

    def test_non_zero_indices(self):
        m = LocalMatrix(jnp.asarray([[0.0, 2.0, 0.0, 3.0]]))
        idx, mask = m.non_zero_indices(0, max_nnz=4)
        got = sorted(int(i) for i, v in zip(np.asarray(idx), np.asarray(mask)) if v)
        assert got == [1, 3]


class TestPytree:
    def test_usable_under_jit(self, rng):
        a = _mat(rng, 4, 4)

        @jax.jit
        def f(m: LocalMatrix):
            return m.times(m.T)

        np.testing.assert_allclose(f(a).data,
                                   np.asarray(a.data) @ np.asarray(a.data).T,
                                   rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 8), k=st.integers(1, 8), n=st.integers(1, 8),
       seed=st.integers(0, 2**16))
def test_matmul_matches_numpy_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    A = np.asarray(rng.normal(size=(m, k)), np.float32)
    B = np.asarray(rng.normal(size=(k, n)), np.float32)
    got = LocalMatrix(jnp.asarray(A)).times(LocalMatrix(jnp.asarray(B)))
    np.testing.assert_allclose(got.data, A @ B, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 6), n=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_on_then_roundtrip_property(m, n, seed):
    """(a on b)[:m] == a and (a then b)[:, :n] == a."""
    rng = np.random.default_rng(seed)
    a = LocalMatrix(jnp.asarray(rng.normal(size=(m, n)), jnp.float32))
    b = LocalMatrix(jnp.asarray(rng.normal(size=(m, n)), jnp.float32))
    np.testing.assert_array_equal(a.on(b).data[:m], a.data)
    np.testing.assert_array_equal(a.then(b).data[:, :n], a.data)
