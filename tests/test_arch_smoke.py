"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED same-family config, runs one forward + one train step + one decode
step on CPU with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke
from repro.models.transformer import TransformerLM, init_model
from repro.train.step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _extras(cfg, batch_size):
    kw = {}
    if cfg.vision_tokens:
        kw["vision_embeds"] = jnp.ones((batch_size, cfg.vision_tokens,
                                        cfg.d_model), jnp.float32)
    if cfg.encoder_layers:
        kw["encoder_frames"] = jnp.ones((batch_size, cfg.encoder_seq,
                                         cfg.d_model), jnp.float32)
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke(arch)
        model = TransformerLM(cfg)
        params, _ = init_model(KEY, cfg)
        logits, aux = model.forward(params, jnp.ones((B, S), jnp.int32),
                                    **_extras(cfg, B))
        expect_s = S + (cfg.vision_tokens or 0)
        assert logits.shape == (B, expect_s, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_train_step(self, arch):
        from repro.optim.optimizers import adamw
        cfg = get_smoke(arch)
        opt = adamw(lr=1e-3, warmup=0)   # warmup=0: step-0 LR is nonzero
        state, _ = init_train_state(KEY, cfg, opt)
        step = make_train_step(cfg, opt)
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "labels": jnp.ones((B, S), jnp.int32), **_extras(cfg, B)}
        # copy before stepping: the jitted step donates its input state
        d0 = np.asarray(jax.tree.leaves(state.params)[0], np.float32)
        state2, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        assert int(state2.step) == 1
        # params actually changed
        d1 = np.asarray(jax.tree.leaves(state2.params)[0], np.float32)
        assert not np.allclose(d0, d1)

    def test_prefill_decode(self, arch):
        cfg = get_smoke(arch)
        model = TransformerLM(cfg)
        params, _ = init_model(KEY, cfg)
        cache = model.init_cache(B, 128)
        logits, cache = model.prefill(params, jnp.ones((B, S), jnp.int32),
                                      cache, **_extras(cfg, B))
        assert logits.shape == (B, 1, cfg.vocab_size)
        pos0 = S + (cfg.vision_tokens or 0)
        logits, cache = model.decode_step(params, jnp.ones((B, 1), jnp.int32),
                                          jnp.asarray(pos0), cache)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "llama4-scout-17b-16e": (48, 5120, 40, 8, 8192, 202048, 16),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768, 8),
        "whisper-small": (12, 768, 12, 12, 3072, 51865, 0),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155, 0),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000, 0),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064, 0),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000, 0),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144, 0),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280, 0),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936, 0),
    }
    for arch, (L, d, h, kv, ff, vocab, experts) in expect.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size, cfg.num_experts)
        assert got == (L, d, h, kv, ff, vocab, experts), f"{arch}: {got}"


def test_smoke_configs_are_reduced():
    for arch in ARCH_IDS:
        cfg = get_smoke(arch)
        assert cfg.num_layers <= 4
        assert cfg.d_model <= 512
        assert cfg.num_experts <= 4


def test_input_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1
