"""PCA and Gaussian Naive Bayes through the MLI contract — the paper's
'naturally extends to a diverse group of ML algorithms' claim exercised
beyond GLMs."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.algorithms.naive_bayes import (GaussianNaiveBayes,
                                               NaiveBayesParameters)
from repro.core.algorithms.pca import PCA, PCAParameters
from repro.core.numeric_table import MLNumericTable


class TestPCA:
    def _data(self, rng, n=256, d=6):
        # anisotropic gaussian: two dominant directions
        scales = np.array([5.0, 3.0, 0.5, 0.3, 0.2, 0.1][:d])
        X = rng.normal(size=(n, d)) * scales + 2.0
        return np.asarray(X, np.float32)

    def test_matches_numpy_svd(self, rng):
        X = self._data(rng)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        model = PCA.train(t, PCAParameters(n_components=2))
        # reference: numpy svd of the centered data
        Xc = X - X.mean(0)
        _, s, vt = np.linalg.svd(Xc, full_matrices=False)
        for k in range(2):
            cos = abs(float(np.asarray(model.components[k]) @ vt[k]))
            assert cos > 0.99, f"PC{k} misaligned: |cos|={cos}"
        np.testing.assert_allclose(np.asarray(model.explained_variance),
                                   (s[:2] ** 2) / len(X), rtol=0.02)

    def test_shard_invariance(self, rng):
        X = self._data(rng, n=64)
        outs = []
        for shards in (1, 2, 8):
            t = MLNumericTable.from_numpy(X, num_shards=shards)
            m = PCA.train(t, PCAParameters(n_components=2))
            outs.append(np.abs(np.asarray(m.components)))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-3, atol=1e-4)

    def test_reconstruction(self, rng):
        X = self._data(rng)
        t = MLNumericTable.from_numpy(X, num_shards=4)
        m = PCA.train(t, PCAParameters(n_components=4))
        Xr = np.asarray(m.inverse_transform(m.transform(jnp.asarray(X))))
        # 4 of 6 dims capture almost all the anisotropic variance
        rel = np.linalg.norm(X - Xr) / np.linalg.norm(X - X.mean(0))
        assert rel < 0.2


class TestGaussianNaiveBayes:
    def _blobs(self, rng, n_per=128, d=4, C=3):
        centers = rng.normal(size=(C, d)) * 4
        X = np.concatenate([rng.normal(size=(n_per, d)) + centers[c]
                            for c in range(C)]).astype(np.float32)
        y = np.repeat(np.arange(C), n_per).astype(np.float32)
        perm = rng.permutation(len(y))
        return X[perm], y[perm]

    def test_separable_blobs(self, rng):
        X, y = self._blobs(rng)
        data = np.concatenate([y[:, None], X], 1)
        t = MLNumericTable.from_numpy(data, num_shards=4)
        model = GaussianNaiveBayes.train(t, NaiveBayesParameters(num_classes=3))
        pred = np.asarray(model.predict(jnp.asarray(X)))
        assert (pred == y).mean() > 0.95

    def test_priors_sum_to_one(self, rng):
        X, y = self._blobs(rng)
        data = np.concatenate([y[:, None], X], 1)
        t = MLNumericTable.from_numpy(data, num_shards=4)
        model = GaussianNaiveBayes.train(t, NaiveBayesParameters(num_classes=3))
        assert abs(float(jnp.sum(model.priors)) - 1.0) < 1e-5


@settings(max_examples=10, deadline=None)
@given(shards=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**16))
def test_nb_shard_invariance_property(shards, seed):
    rng = np.random.default_rng(seed)
    X = np.asarray(rng.normal(size=(32, 3)), np.float32)
    y = np.asarray(rng.integers(0, 2, 32), np.float32)
    data = np.concatenate([y[:, None], X], 1)
    t = MLNumericTable.from_numpy(data, num_shards=shards)
    m = GaussianNaiveBayes.train(t, NaiveBayesParameters(num_classes=2))
    t1 = MLNumericTable.from_numpy(data, num_shards=1)
    m1 = GaussianNaiveBayes.train(t1, NaiveBayesParameters(num_classes=2))
    np.testing.assert_allclose(np.asarray(m.means), np.asarray(m1.means),
                               rtol=1e-4, atol=1e-5)
