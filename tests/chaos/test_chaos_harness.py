"""Mechanics of the chaos harness itself: the ``chaos_hosts`` fixture, the
three fault actions, and the ParamStore bulletin board they act on.

These hosts are deliberately jax-free (plain numpy trees through the
exchange store) so the harness contract — faults fire inside the victim at
a deterministic round, exits carry the right codes, peers observe deaths
and departures — is pinned down fast, independent of any training loop.
"""
import signal

import numpy as np
import pytest

from repro.core.exchange import ParamStore, PeerTimeout
from repro.testing.chaos import ChaosInjector, Fault

pytestmark = pytest.mark.skipif(not hasattr(signal, "SIGKILL"),
                                reason="POSIX-only kill semantics")

# A minimal SSP-disciplined host: publish a recognizable tree each round,
# wait for peers under the staleness bound, read their freshest in-bound
# publication.  The injector is consulted at every round boundary, exactly
# where the stream wrapper would consult it in a real training loop.
_HOST = """
import json, os
import numpy as np

from repro.core.exchange import ParamStore
from repro.testing.chaos import ChaosInjector

HOST = int(os.environ["REPRO_HOST_ID"])
N = int(os.environ["REPRO_NUM_HOSTS"])
ROUNDS = int(os.environ["ROUNDS"])
S = int(os.environ.get("STALENESS", "0"))

store = ParamStore(os.environ["STORE_ROOT"], HOST, N, timeout=60.0)
injector = ChaosInjector.from_env(store=store)

reads = []
for r in range(ROUNDS):
    injector.step(r)
    store.publish(r, {"v": np.full(4, 10 * HOST + r, np.float32)})
    got = {}
    for p in store.peers():
        clock = store.wait_clock(p, r - S + 1)
        if clock <= r - S:
            continue  # departed and out of bound
        res = store.read_at_most(p, min(clock - 1, r),
                                 {"v": np.zeros(4, np.float32)})
        if res is None:
            continue  # peer has nothing old enough yet (early rounds, s>0)
        tree, tau = res
        assert tree["v"][0] == 10 * p + tau, (p, tau, tree)
        got[p] = tau
    reads.append(got)
print("RESULT::" + json.dumps({
    "host": HOST, "reads": reads,
    "delays": len(injector.injected),
    "clocks": store.clocks()}))
"""


def test_all_hosts_clean_without_faults(chaos_hosts, tmp_path):
    """Baseline: 3 independent hosts, lock-step (s=0), every read exact."""
    runs = chaos_hosts(_HOST, hosts=3, devices_per_host=1, global_mesh=False,
                       env={"ROUNDS": "4", "STORE_ROOT": str(tmp_path / "x")})
    for r in runs:
        res = r.result()
        peers = {str(p) for p in range(3) if p != r.host_id}
        # s=0 lock-step: every round reads every peer's *current* round
        assert res["reads"] == [{p: rd for p in peers} for rd in range(4)]
        assert res["clocks"] == {"0": 4, "1": 4, "2": 4}


def test_kill_fault_sigkills_victim_at_its_round(chaos_hosts, tmp_path):
    """A kill fault SIGKILLs exactly the targeted host at the targeted
    round; rounds before it completed, nothing after it ran."""
    runs = chaos_hosts(
        _HOST, hosts=2, devices_per_host=1, global_mesh=False, check=False,
        faults=[Fault(host=1, round=2, action="kill")],
        env={"ROUNDS": "4", "STALENESS": "3",
             "STORE_ROOT": str(tmp_path / "x")})
    survivor, victim = runs
    assert victim.killed, (victim.returncode, victim.stderr[-500:])
    assert "RESULT::" not in victim.stdout  # died mid-run, no final print
    # the victim published rounds 0 and 1, then died asking for round 2
    store = ParamStore(str(tmp_path / "x"), 0, 2)
    assert store.clock(1) == 2
    # the survivor (staleness 3 covers the gap) finished all 4 rounds,
    # reading the victim's last publication (round 1) for the tail rounds
    assert survivor.returncode == 0, survivor.stderr[-500:]
    res = survivor.result()
    assert res["reads"][-1] == {"1": 1}
    assert res["clocks"]["0"] == 4


def test_delay_fault_makes_a_straggler(chaos_hosts, tmp_path):
    """A delay fault sleeps inside the victim (recorded in .injected) and
    the cohort still completes — a straggler, not a death."""
    runs = chaos_hosts(
        _HOST, hosts=2, devices_per_host=1, global_mesh=False,
        faults=[Fault(host=0, round=1, action="delay", seconds=0.4)],
        env={"ROUNDS": "3", "STORE_ROOT": str(tmp_path / "x")})
    assert runs[0].result()["delays"] == 1
    assert runs[1].result()["delays"] == 0  # fault targeted host 0 only
    for r in runs:
        assert r.result()["clocks"] == {"0": 3, "1": 3}


def test_drop_fault_departs_gracefully(chaos_hosts, tmp_path):
    """A drop fault marks the host departed and exits DROP_EXIT_CODE; the
    peer stops waiting for it immediately (no timeout) and finishes."""
    runs = chaos_hosts(
        _HOST, hosts=2, devices_per_host=1, global_mesh=False, check=False,
        faults=[Fault(host=1, round=2, action="drop")],
        env={"ROUNDS": "4", "STORE_ROOT": str(tmp_path / "x")})
    survivor, dropped = runs
    assert dropped.dropped, (dropped.returncode, dropped.stderr[-500:])
    assert survivor.returncode == 0, survivor.stderr[-500:]
    store = ParamStore(str(tmp_path / "x"), 0, 2)
    assert store.has_left(1)
    assert 1 not in store.peers()
    # the survivor kept running after the departure: its clock reached 4
    assert survivor.result()["clocks"]["0"] == 4


def test_wait_clock_timeout_names_the_corpse(tmp_path):
    """A dead peer (never publishes) surfaces as PeerTimeout carrying WHO
    stalled the mesh — the signal an elastic controller resizes on."""
    store = ParamStore(str(tmp_path / "x"), 0, 2, timeout=0.2)
    store.publish(0, {"v": np.zeros(2, np.float32)})
    with pytest.raises(PeerTimeout) as ei:
        store.wait_clock(1, 1)
    assert ei.value.peer == 1
    assert ei.value.wanted_round == 0


def test_injector_inert_without_spec():
    """No REPRO_CHAOS in the environment -> injector does nothing, so
    programs can install it unconditionally."""
    inj = ChaosInjector.from_env(host_id=0)
    assert not inj
    for r in range(5):
        inj.step(r)  # must not raise, sleep, or kill
    assert inj.injected == []


def test_fault_validation():
    with pytest.raises(ValueError, match="unknown fault action"):
        Fault(host=0, round=1, action="explode")
    with pytest.raises(ValueError, match="seconds > 0"):
        Fault(host=0, round=1, action="delay")
    with pytest.raises(ValueError, match="two faults"):
        ChaosInjector([Fault(0, 1, "kill"), Fault(0, 1, "delay", 1.0)])


def test_wrap_stream_injects_by_stream_step():
    """The stream wrapper keys faults off the underlying stream position,
    proxying the runner-facing surface (step/seek/source/next)."""
    from repro.data.pipeline import BatchIterator

    def source(step):
        return {"data": np.full((4, 2), step, np.float32)}

    hits = []

    class Recorder(ChaosInjector):
        def step(self, round_index):
            hits.append(round_index)
            super().step(round_index)

    stream = Recorder([]).wrap_stream(BatchIterator(source))
    next(stream)
    next(stream)
    assert hits == [0, 1]
    assert stream.step == 2
    stream.seek(7)
    batch = next(stream)
    assert hits == [0, 1, 7]
    assert float(np.asarray(batch["data"])[0, 0]) == 7.0
    assert callable(stream.source)
