"""Property tests for the stale-synchronous discipline and elastic plans.

The SSP invariant (Petuum's bounded-staleness guarantee) on the executable
spec :func:`repro.core.collectives.ssp_trace`: for random (workers, rounds,
staleness) configurations with random per-round durations,

  * no worker ever merges a peer value older than ``staleness`` rounds
    behind its own round, and never one from its future;
  * ``staleness=0`` degenerates to exactly the BSP trace — every worker
    reads every peer's *current* round, every round.

Plus the pure read rule itself (:func:`ssp_read_round`) and the elastic
:func:`repro.core.partition.plan_resize` invariants.  These are in-process
properties (no subprocesses); the executor-level twin — real host
processes exchanging through a ParamStore — is ``test_ssp_executor.py``.
"""
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.collectives import (
    SyncPolicy,
    ssp_read_round,
    ssp_trace,
)
from repro.core.partition import plan_resize


@settings(max_examples=60, deadline=None)
@given(
    workers=st.integers(min_value=1, max_value=6),
    rounds=st.integers(min_value=1, max_value=12),
    staleness=st.integers(min_value=0, max_value=4),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_ssp_trace_respects_staleness_bound(workers, rounds, staleness, seed):
    """No read older than s rounds behind the reader, none from its future."""
    import random

    rng = random.Random(seed)
    durations = [[rng.randint(1, 50) for _ in range(rounds)]
                 for _ in range(workers)]
    trace = ssp_trace(durations, staleness)
    assert len(trace) == workers and all(len(t) == rounds for t in trace)
    for w, worker_trace in enumerate(trace):
        for r, reads in enumerate(worker_trace):
            assert set(reads) == {p for p in range(workers) if p != w}
            for peer, read_round in reads.items():
                assert read_round <= r, (
                    f"worker {w} round {r} read peer {peer}'s round "
                    f"{read_round} — from its own future")
                assert read_round >= r - staleness, (
                    f"worker {w} round {r} read peer {peer}'s round "
                    f"{read_round} — older than the staleness bound "
                    f"{staleness}")


@settings(max_examples=40, deadline=None)
@given(
    workers=st.integers(min_value=2, max_value=6),
    rounds=st.integers(min_value=1, max_value=10),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_staleness_zero_is_exactly_bsp(workers, rounds, seed):
    """s=0: every worker reads every peer's round r at round r — the BSP
    lock-step trace, regardless of how skewed the durations are."""
    import random

    rng = random.Random(seed)
    durations = [[rng.randint(1, 100) for _ in range(rounds)]
                 for _ in range(workers)]
    trace = ssp_trace(durations, staleness=0)
    for worker_trace in trace:
        for r, reads in enumerate(worker_trace):
            assert all(read_round == r for read_round in reads.values()), (
                f"round {r} reads {reads} != pure BSP")


@settings(max_examples=60, deadline=None)
@given(
    my_round=st.integers(min_value=0, max_value=50),
    ahead=st.integers(min_value=0, max_value=10),
    staleness=st.integers(min_value=0, max_value=5),
)
def test_ssp_read_round_caps_at_own_round(my_round, ahead, staleness):
    """A peer running ahead is read at the reader's own round, never newer;
    a peer within the bound is read at its freshest round."""
    peer_clock = my_round - staleness + 1 + ahead  # just inside the bound +
    if peer_clock <= 0:
        return
    got = ssp_read_round(my_round, peer_clock, staleness)
    assert got == min(peer_clock - 1, my_round)
    assert my_round - staleness <= got <= my_round


def test_ssp_read_round_rejects_stale_peer():
    """A peer at or beyond the bound is not readable — the caller must
    block (that wait IS the SSP synchronization)."""
    with pytest.raises(ValueError, match="SSP requires blocking"):
        ssp_read_round(5, 3, staleness=2)  # peer published only rounds 0..2
    assert ssp_read_round(5, 4, staleness=2) == 3


def test_sync_policy_parse_and_modes():
    assert SyncPolicy.parse(None).mode == "bsp"
    assert SyncPolicy.parse(0).mode == "bsp"
    assert SyncPolicy.parse(3) == SyncPolicy(staleness=3)
    assert SyncPolicy.parse(3).mode == "ssp"
    p = SyncPolicy(staleness=2, elastic=True)
    assert SyncPolicy.parse(p) is p
    with pytest.raises(ValueError):
        SyncPolicy(staleness=-1)


@settings(max_examples=40, deadline=None)
@given(
    per=st.integers(min_value=1, max_value=8),
    old=st.integers(min_value=1, max_value=8),
    new=st.integers(min_value=1, max_value=8),
)
def test_plan_resize_row_conservation(per, old, new):
    """Every row has exactly one owner on each side; moved_rows is zero
    exactly when the layout is unchanged."""
    rows = per * old * new  # divisible by construction
    plan = plan_resize(rows, old, new)
    assert plan.old_rows_per_shard * old == rows
    assert plan.new_rows_per_shard * new == rows
    for r in (0, rows - 1, rows // 2):
        assert 0 <= plan.owner(r, new=False) < old
        assert 0 <= plan.owner(r, new=True) < new
    if old == new:
        assert plan.moved_rows == 0
    assert 0 <= plan.moved_rows <= rows
    assert f"{old} -> {new}" in plan.describe()


def test_plan_resize_rejects_indivisible():
    with pytest.raises(ValueError, match="new partitions"):
        plan_resize(10, 2, 3)
    with pytest.raises(ValueError, match="old partitions"):
        plan_resize(10, 3, 2)
    with pytest.raises(ValueError, match=">= 1"):
        plan_resize(10, 0, 2)
