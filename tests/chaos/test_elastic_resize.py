"""Elastic resize under a real SIGKILL: live migration as
checkpoint-and-restart, proven bit-for-bit.

The scenario the ISSUE calls the tentpole's proof: a 2-host x 2-device BSP
mesh is training with per-epoch atomic checkpoints when one host is
SIGKILLed mid-stream (generation 0).  The :class:`ElasticController`
detects the death, kills the hung survivor (a BSP collective would wait on
the corpse forever), shrinks the world, and spawns generation 1 — one host,
2 devices — which resumes from the newest snapshot with
``allow_resize=True``, repartitioning 4 stream shards onto 2 through
:func:`repro.core.partition.plan_resize`.

Correctness bar: the migrated run's final model must be **bit-identical**
to an uninterrupted small-mesh run resumed from that same snapshot, and
the stream must land on exactly the same step — elasticity changed where
the rows live, not what was computed.
"""
import json
import os
import shutil
import signal
import sys

import pytest

from conftest import REPO, run_devices_subprocess

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(not hasattr(signal, "SIGKILL"),
                       reason="POSIX-only kill semantics"),
]

ROWS, F, E, KILL_AT = 64, 3, 6, 2

_CHILD = """
import hashlib, json, os

from repro.core import hostmesh

info = hostmesh.initialize_from_env()

import jax, jax.numpy as jnp
import numpy as np

from repro.core.compat import make_mesh
from repro.core.runner import CheckpointPolicy, DistributedRunner
from repro.data import BatchIterator
from repro.testing import ChaosInjector

ROWS, F, E = %(ROWS)d, %(F)d, %(E)d


def source(step):
    rng = np.random.RandomState(step)
    return {"data": rng.randn(ROWS, F + 1).astype(np.float32)}


def local_step(block, state, r):
    x, y = block[:, :F], block[:, F]
    g = x.T @ (x @ state - y) / block.shape[0]
    return state - 0.1 * g


mesh = make_mesh((len(jax.devices()),), ("data",))
runner = DistributedRunner(mesh=mesh, schedule="gather_broadcast")
stream = ChaosInjector.from_env().wrap_stream(BatchIterator(source, mesh=mesh))
ck = CheckpointPolicy(os.environ["CKPT_DIR"], every_epochs=1)

resumed_from = None
if os.environ.get("REPRO_RESUME") == "1":
    step = os.environ.get("RESUME_STEP")
    if step:
        resumed_from = int(step)
    else:
        from repro.checkpoint import latest_step
        resumed_from = latest_step(os.environ["CKPT_DIR"])
    w = runner.resume(os.environ["CKPT_DIR"], stream,
                      jnp.zeros((F,), jnp.float32), local_step, E,
                      combine="mean", checkpoint=ck, allow_resize=True,
                      step=resumed_from)
else:
    w = runner.run_epochs(stream, jnp.zeros((F,), jnp.float32), local_step, E,
                          combine="mean", chunks_per_epoch=1, checkpoint=ck)

out = hostmesh.fetch(w)
print("RESULT::" + json.dumps({
    "sha": hashlib.sha256(out.tobytes()).hexdigest()[:16],
    "w": out.tolist(), "stream_step": stream.step,
    "resumed_from": resumed_from,
    "generation": int(os.environ.get("REPRO_GENERATION", "0")),
    "num_shards": runner.num_shards,
    "process_count": jax.process_count()}))
"""


def _result(stdout: str) -> dict:
    lines = [l for l in stdout.splitlines() if l.startswith("RESULT::")]
    assert lines, f"no RESULT:: line in output:\n{stdout[-2000:]}"
    return json.loads(lines[-1][len("RESULT::"):])


def test_sigkilled_host_triggers_resize_and_bitexact_resume(tmp_path):
    from repro.launch.elastic import ElasticController
    from repro.testing import Fault

    prog = _CHILD % {"ROWS": ROWS, "F": F, "E": E}
    ckpt = tmp_path / "ck"

    controller = ElasticController(
        [sys.executable, "-c", prog], num_hosts=2, devices_per_host=2,
        env={"PYTHONPATH": os.path.join(REPO, "src"),
             "CKPT_DIR": str(ckpt)},
        faults=[Fault(host=1, round=KILL_AT, action="kill")],
        max_restarts=1, min_hosts=1, timeout=300.0)
    report = controller.run()

    # generation 0 (2 hosts) lost host 1 to the SIGKILL; generation 1
    # completed on the shrunken world
    assert report.resized
    assert [g.num_hosts for g in report.generations] == [2, 1]
    assert [e.host_id for e in report.generations[0].deaths] == [1]
    assert len(report.restart_seconds) == 1
    assert report.restart_seconds[0] > 0

    migrated = _result(report.host_output(0))
    assert migrated["generation"] == 1
    assert migrated["process_count"] == 1
    assert migrated["num_shards"] == 2  # the resize actually happened
    assert migrated["stream_step"] == E  # stream position exact
    # the victim died asking for epoch KILL_AT's window, so the newest
    # snapshot generation 1 could restart from is KILL_AT (or KILL_AT-1 if
    # the controller's SIGKILL outraced the survivor's snapshot write —
    # either way a genuinely mid-stream snapshot, never a fresh start)
    assert 1 <= migrated["resumed_from"] <= KILL_AT

    # ground truth: an uninterrupted small-mesh run resumed from the SAME
    # snapshot (a copy, so its own checkpoints don't disturb the original)
    ref_dir = tmp_path / "ref"
    shutil.copytree(ckpt, ref_dir)
    ref = _result(run_devices_subprocess(
        prog, devices=2,
        env={"CKPT_DIR": str(ref_dir), "REPRO_RESUME": "1",
             "RESUME_STEP": str(migrated["resumed_from"])}).stdout)
    assert ref["stream_step"] == E
    assert migrated["sha"] == ref["sha"], (migrated["w"], ref["w"])
    assert migrated["w"] == ref["w"]
