"""The SSP executor on real host processes: ``run_epochs_ssp`` hosts
exchanging through a shared ParamStore, faults injected by the chaos
harness.

Three proofs, matching the property-level suite one layer down:

  * **s=0 is BSP, bitwise** — two independent host processes produce
    bit-identical models (mean *and* sum lanes), equal to an in-process
    sequential reference simulator that replays the publish/merge
    arithmetic one host at a time.
  * **the staleness bound holds on real clocks** — under an injected
    straggler the executor's trace shows reads that are genuinely stale
    (SSP decoupled the fast host) yet never older than ``s`` rounds.
  * **a SIGKILLed host rejoins** — ``resume_ssp`` restarts the victim from
    its own atomic checkpoint against the same store; the cohort only
    blocks for the restart gap and the final models match the
    uninterrupted run bit-for-bit.
"""
import os
import signal
import subprocess
import sys

import pytest

from conftest import REPO, describe_failure, result_json

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(not hasattr(signal, "SIGKILL"),
                       reason="POSIX-only kill semantics"),
]

E, DEV, ROWS, F = 4, 2, 32, 3

# One SSP host: mean-lane SGD plus sum-lane sufficient statistics, each
# host streaming its own shard of the data (source keyed by host id).
_HOST = """
import hashlib, json, os
import numpy as np
import jax, jax.numpy as jnp

from repro.core.compat import make_mesh
from repro.core.exchange import ParamStore
from repro.core.runner import CheckpointPolicy, DistributedRunner
from repro.data.pipeline import BatchIterator
from repro.testing import ChaosInjector

HOST = int(os.environ["REPRO_HOST_ID"])
N = int(os.environ["REPRO_NUM_HOSTS"])
ROOT = os.environ["STORE_ROOT"]
ROWS, F, E = %(ROWS)d, %(F)d, int(os.environ.get("EPOCHS", "%(E)d"))
S = int(os.environ.get("STALENESS", "0"))


def source(step):
    rng = np.random.RandomState(1000 * HOST + step)
    return {"data": rng.randn(ROWS, F + 1).astype(np.float32)}


def local_step(block, state, r):
    x, y = block[:, :F], block[:, F]
    g = x.T @ (x @ state - y) / block.shape[0]
    return state - 0.1 * g


def stats_step(block, state, r):
    x = block[:, :F]
    m = (x @ state > 0).astype(jnp.float32)
    return {"n": jnp.sum(m), "s": x.T @ m}


def update(state, merged, r):
    return merged["s"] / jnp.maximum(merged["n"], 1.0)


def sha(x):
    return hashlib.sha256(np.asarray(jax.device_get(x)).tobytes()) \\
        .hexdigest()[:16]


mesh = make_mesh((len(jax.devices()),), ("data",))
runner = DistributedRunner(mesh=mesh, schedule="gather_broadcast")
store = ParamStore(ROOT, HOST, N, timeout=300.0, keep=S + 2)
stream = ChaosInjector.from_env(store=store).wrap_stream(
    BatchIterator(source, mesh=mesh))

trace = []
ckpt = None
if os.environ.get("CKPT_BASE"):
    ckpt = CheckpointPolicy(os.path.join(os.environ["CKPT_BASE"],
                                         "h%%d" %% HOST), every_epochs=1)
if os.environ.get("REPRO_RESUME") == "1":
    w = runner.resume_ssp(ckpt.ckpt_dir, stream, jnp.zeros((F,), jnp.float32),
                          local_step, E, store=store, combine="mean",
                          trace=trace)
else:
    w = runner.run_epochs_ssp(stream, jnp.zeros((F,), jnp.float32),
                              local_step, E, store=store, staleness=S,
                              combine="mean", chunks_per_epoch=2,
                              checkpoint=ckpt, trace=trace)

out = {"host": HOST, "mean_sha": sha(w), "mean_w": np.asarray(w).tolist(),
       "trace": [{"epoch": t["epoch"],
                  "reads": {str(k): v for k, v in t["reads"].items()}}
                 for t in trace]}

if os.environ.get("SUM_LANE") == "1":
    store2 = ParamStore(ROOT + "_sum", HOST, N, timeout=300.0, keep=S + 2)
    c = runner.run_epochs_ssp(BatchIterator(source, mesh=mesh),
                              jnp.ones((F,), jnp.float32), stats_step, E,
                              store=store2, staleness=S, combine="sum",
                              update=update)
    out["sum_sha"] = sha(c)
print("RESULT::" + json.dumps(out))
"""

# Sequential reference simulator for s=0: one process replays both lanes
# host-at-a-time through the SAME executor arithmetic — the local epoch via
# a solo (single-host) run_epochs_ssp call, the cross-host merge via the
# canonical stack-then-reduce in host-id order.  Bit-identity against the
# real two-process cohort is the determinism contract of the SSP lane.
_REFERENCE = """
import hashlib, json, os
import numpy as np
import jax, jax.numpy as jnp

from repro.core.compat import make_mesh
from repro.core.exchange import ParamStore
from repro.core.runner import DistributedRunner
from repro.data.pipeline import BatchIterator

N = int(os.environ["REPRO_NUM_HOSTS"])
ROOT = os.environ["STORE_ROOT"]
ROWS, F, E = %(ROWS)d, %(F)d, %(E)d


def make_source(host):
    def source(step):
        rng = np.random.RandomState(1000 * host + step)
        return {"data": rng.randn(ROWS, F + 1).astype(np.float32)}
    return source


def local_step(block, state, r):
    x, y = block[:, :F], block[:, F]
    g = x.T @ (x @ state - y) / block.shape[0]
    return state - 0.1 * g


def stats_step(block, state, r):
    x = block[:, :F]
    m = (x @ state > 0).astype(jnp.float32)
    return {"n": jnp.sum(m), "s": x.T @ m}


def update(state, merged, r):
    return merged["s"] / jnp.maximum(merged["n"], 1.0)


def sha(x):
    return hashlib.sha256(np.asarray(jax.device_get(x)).tobytes()) \\
        .hexdigest()[:16]


mesh = make_mesh((len(jax.devices()),), ("data",))
runner = DistributedRunner(mesh=mesh, schedule="gather_broadcast")
streams = [BatchIterator(make_source(h), mesh=mesh) for h in range(N)]

# mean lane: epoch e computes every host's local epoch from the shared
# post-merge state, then all hosts adopt the mean (s=0 lock-step).  Each
# local epoch runs through run_epochs_ssp itself against a throwaway
# single-host store, so the jitted path is exactly the executor's.
w = jnp.zeros((F,), jnp.float32)
for e in range(E):
    mines = []
    for h in range(N):
        solo = ParamStore(os.path.join(ROOT, "solo_m%%d_%%d" %% (h, e)), 0, 1)
        mines.append(runner.run_epochs_ssp(
            streams[h], w, local_step, e + 1, store=solo, staleness=0,
            combine="mean", chunks_per_epoch=2, start_epoch=e))
    w = jax.tree.map(lambda *xs: jnp.mean(jnp.stack(xs, axis=0), axis=0),
                     *[jax.tree.map(np.asarray, jax.device_get(m))
                       for m in mines])

# sum lane: per-round sufficient statistics summed across hosts, state
# rebuilt by update — the partition_apply call is the executor's own.
streams2 = [BatchIterator(make_source(h), mesh=mesh) for h in range(N)]
c = jnp.ones((F,), jnp.float32)
for e in range(E):
    stats = []
    for h in range(N):
        batch = next(streams2[h])
        mine = runner.partition_apply(batch["data"], stats_step,
                                      broadcast=(c, jnp.asarray(e, jnp.int32)),
                                      combine="sum")
        stats.append(jax.tree.map(np.asarray, jax.device_get(mine)))
    merged = jax.tree.map(lambda *xs: jnp.sum(jnp.stack(xs, axis=0), axis=0),
                          *stats)
    c = update(c, merged, jnp.asarray(e, jnp.int32))

print("RESULT::" + json.dumps({"mean_sha": sha(w), "sum_sha": sha(c),
                               "mean_w": np.asarray(w).tolist()}))
"""


def test_s0_bit_identical_across_hosts_and_vs_reference(chaos_hosts,
                                                        tmp_path):
    """Two real host processes at s=0: both lanes bit-identical on every
    host AND bit-identical to the sequential reference simulator."""
    runs = chaos_hosts(
        _HOST % {"ROWS": ROWS, "F": F, "E": E}, hosts=2,
        devices_per_host=DEV, global_mesh=False,
        env={"STORE_ROOT": str(tmp_path / "x"), "SUM_LANE": "1"})
    h0, h1 = (r.result() for r in runs)
    assert h0["mean_sha"] == h1["mean_sha"]
    assert h0["sum_sha"] == h1["sum_sha"]

    from conftest import run_devices_subprocess

    ref = result_json(run_devices_subprocess(
        _REFERENCE % {"ROWS": ROWS, "F": F, "E": E}, devices=DEV,
        env={"REPRO_NUM_HOSTS": "2",
             "STORE_ROOT": str(tmp_path / "ref")}))
    assert h0["mean_sha"] == ref["mean_sha"], (h0["mean_w"], ref["mean_w"])
    assert h0["sum_sha"] == ref["sum_sha"]
    # s=0 trace is pure lock-step: round e reads every peer's round e
    for r in (h0, h1):
        peer = str(1 - r["host"])
        assert [t["reads"] for t in r["trace"]] == \
            [{peer: e} for e in range(E)]


def test_staleness_bound_holds_under_injected_straggler(chaos_hosts,
                                                        tmp_path):
    """A 1s delay on host 1: host 0 runs ahead on stale reads — genuinely
    stale (SSP decoupled it) but never more than s rounds old."""
    from repro.testing import Fault

    s = 2
    runs = chaos_hosts(
        _HOST % {"ROWS": ROWS, "F": F, "E": 6}, hosts=2,
        devices_per_host=DEV, global_mesh=False,
        faults=[Fault(host=1, round=2, action="delay", seconds=1.0)],
        env={"STORE_ROOT": str(tmp_path / "x"), "EPOCHS": "6",
             "STALENESS": str(s)})
    stale_reads = 0
    for r in runs:
        res = r.result()
        for t in res["trace"]:
            for read_round in t["reads"].values():
                assert t["epoch"] - s <= read_round <= t["epoch"], (
                    f"host {res['host']} epoch {t['epoch']} read round "
                    f"{read_round}: outside the staleness bound {s}")
                stale_reads += read_round < t["epoch"]
    assert stale_reads > 0, \
        "delay fault produced no stale reads — SSP never decoupled"


def test_sigkilled_host_resumes_and_cohort_converges(tmp_path):
    """Kill host 1 mid-run; restart it with resume_ssp against the same
    store.  Both finals must equal the uninterrupted cohort bit-for-bit
    (s=0 lock-step is deterministic, so recovery is provable by equality).
    """
    from repro.testing import Fault, faults_to_env

    prog = _HOST % {"ROWS": ROWS, "F": F, "E": E}

    def host_env(h, extra):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src"),
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={DEV}",
                   REPRO_NUM_HOSTS="2", REPRO_HOST_ID=str(h))
        env.pop("REPRO_COORDINATOR", None)
        env.update(extra)
        return env

    def spawn(h, extra):
        return subprocess.Popen([sys.executable, "-c", prog],
                                env=host_env(h, extra), cwd=REPO,
                                stdout=subprocess.PIPE,
                                stderr=subprocess.PIPE, text=True)

    # uninterrupted cohort (fresh store) — the ground truth
    base = {"STORE_ROOT": str(tmp_path / "ref")}
    procs = [spawn(h, base) for h in range(2)]
    truth = {}
    for h, p in enumerate(procs):
        out, err = p.communicate(timeout=540)
        assert p.returncode == 0, err[-2000:]
        truth[h] = result_json(
            type("O", (), {"stdout": out, "returncode": 0}))
    assert truth[0]["mean_sha"] == truth[1]["mean_sha"]

    # chaos cohort: host 1 checkpoints every epoch and is SIGKILLed when
    # its stream is asked for epoch 2's window (epochs 0..1 are on disk)
    chaos = {"STORE_ROOT": str(tmp_path / "x"),
             "CKPT_BASE": str(tmp_path / "ck")}
    p0 = spawn(0, dict(chaos))
    p1 = spawn(1, dict(chaos,
                       **faults_to_env([Fault(host=1, round=2,
                                              action="kill")])))
    try:
        assert p1.wait(timeout=300) == -signal.SIGKILL
        # the respawn: resume from the atomic checkpoint, same store —
        # host 0 is still alive, blocked on host 1's round 2
        p1b = spawn(1, dict(chaos, REPRO_RESUME="1"))
        out1, err1 = p1b.communicate(timeout=540)
        assert p1b.returncode == 0, err1[-2000:]
        out0, err0 = p0.communicate(timeout=540)
        assert p0.returncode == 0, err0[-2000:]
    finally:
        for p in (p0, p1):
            if p.poll() is None:
                p.kill()

    r0 = result_json(type("O", (), {"stdout": out0, "returncode": 0}))
    r1 = result_json(type("O", (), {"stdout": out1, "returncode": 0}))
    assert r0["mean_sha"] == r1["mean_sha"] == truth[0]["mean_sha"], (
        r0["mean_w"], r1["mean_w"], truth[0]["mean_w"])
    # the resumed host replayed only the post-checkpoint rounds
    assert r1["trace"][0]["epoch"] == 2
