"""Cross-host determinism of the BSP global mesh.

The same training run on 2 hosts x 4 devices (a real ``jax.distributed``
multi-process mesh, gloo collectives) and on a single process with 8
emulated devices must agree — the device mesh is 8 wide either way, the
data is identical, so any drift is a partitioning or collective bug.

What "agree" means per schedule is itself part of the contract:

  * **k-means on binary-lattice data** is bit-for-bit identical across
    layouts for ALL three collective schedules: the sufficient statistics
    are sums of {0,1} entries and integer counts — exactly representable,
    associativity-exact in float32 — so even the tree-ordered reductions
    (allreduce, reduce_scatter) cannot produce different bits.
  * **logistic regression** (real-valued gradients) is bit-for-bit on
    ``gather_broadcast`` (replicate-then-reduce performs the identical
    arithmetic everywhere) and allclose on the reduction schedules, whose
    float association legitimately differs between device layouts.
"""
import signal

import numpy as np
import pytest

from conftest import run_devices_subprocess

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(not hasattr(signal, "SIGKILL"),
                       reason="POSIX-only multi-process mesh"),
]

_PROG = """
import hashlib, json, os

from repro.core import hostmesh

info = hostmesh.initialize_from_env()

import jax, jax.numpy as jnp
import numpy as np

from repro.core.collectives import CollectiveSchedule
from repro.core.compat import make_mesh
from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm, LogisticRegressionParameters)
from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.data import BatchIterator

ROWS, D, E, CHUNKS = 128, 8, 3, 2
assert len(jax.devices()) == 8, jax.devices()


def clf_source(step):
    rng = np.random.default_rng(1000 + step)
    w = np.linspace(-1, 1, D).astype(np.float32)
    X = rng.normal(size=(ROWS, D)).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    return {"data": np.concatenate([y[:, None], X], 1).astype(np.float32)}


def km_source(step):
    # binary lattice: every coordinate is 0 or 1, so cluster sums and
    # counts are small integers — exact in float32, order-independent
    rng = np.random.default_rng(3000 + step)
    return {"data": rng.integers(0, 2, size=(ROWS, D)).astype(np.float32)}


def sha(x):
    return hashlib.sha256(np.asarray(x).tobytes()).hexdigest()[:16]


out = {"process_count": jax.process_count()}
for sched in CollectiveSchedule:
    mesh = make_mesh((len(jax.devices()),), ("data",))

    p = LogisticRegressionParameters(learning_rate=0.3, local_batch_size=8,
                                     schedule=sched)
    m = LogisticRegressionAlgorithm.train_stream(
        BatchIterator(clf_source, mesh=mesh), p, num_epochs=E,
        chunks_per_epoch=CHUNKS)
    w = hostmesh.fetch(m.weights)
    out["logreg/" + sched.value] = {"sha": sha(w), "w": w.tolist()}

    kp = KMeansParameters(k=4, seed=0, schedule=sched)
    km = KMeans.train_stream(BatchIterator(km_source, mesh=mesh), kp,
                             num_epochs=E, chunks_per_epoch=CHUNKS)
    c = hostmesh.fetch(km.centroids)
    out["kmeans/" + sched.value] = {"sha": sha(c), "c": c.tolist()}
print("RESULT::" + json.dumps(out))
"""

SCHEDULES = ("gather_broadcast", "allreduce", "reduce_scatter")


def test_two_hosts_match_single_process(chaos_hosts):
    """2 hosts x 4 devices == 1 process x 8 devices, per the contract in
    the module docstring, for logreg and k-means under all 3 schedules."""
    single = run_devices_subprocess(_PROG, devices=8)
    from conftest import result_json

    ref = result_json(single)
    assert ref["process_count"] == 1

    runs = chaos_hosts(_PROG, hosts=2, devices_per_host=4, global_mesh=True)
    results = [r.result() for r in runs]
    for res in results:
        assert res["process_count"] == 2

    h0, h1 = results
    for sched in SCHEDULES:
        for algo in ("logreg", "kmeans"):
            key = f"{algo}/{sched}"
            # both hosts fetched the same replicated result
            assert h0[key]["sha"] == h1[key]["sha"], key

        # k-means: bitwise across layouts on every schedule (integer sums)
        assert h0[f"kmeans/{sched}"]["sha"] == ref[f"kmeans/{sched}"]["sha"], (
            sched, h0[f"kmeans/{sched}"]["c"], ref[f"kmeans/{sched}"]["c"])

    # logreg: bitwise where the arithmetic is layout-invariant, allclose
    # where the reduction tree legitimately re-associates floats
    assert h0["logreg/gather_broadcast"]["sha"] == \
        ref["logreg/gather_broadcast"]["sha"], (
            h0["logreg/gather_broadcast"]["w"],
            ref["logreg/gather_broadcast"]["w"])
    for sched in ("allreduce", "reduce_scatter"):
        np.testing.assert_allclose(
            np.asarray(h0[f"logreg/{sched}"]["w"]),
            np.asarray(ref[f"logreg/{sched}"]["w"]),
            rtol=0, atol=1e-5, err_msg=f"logreg/{sched}")
