"""MLTable (paper §III-A, Fig. A1): relational + MapReduce ops, schema,
text featurization (Fig. A2 pipeline front half)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.mltable import MLTable
from repro.core.schema import EMPTY, ColumnType, MLRow, Schema
from repro.features.text import n_grams, tf_idf


@pytest.fixture
def people():
    return MLTable.from_rows(
        [("ann", 34, True, 1.5), ("bob", 21, False, 2.5),
         ("cat", 45, True, 3.5), ("dan", 21, True, 4.5)],
        names=["name", "age", "member", "score"], num_partitions=2)


class TestRelationalOps:
    def test_project(self, people):
        t = people.project(["name", "score"])
        assert t.num_cols == 2 and t.collect()[0] == ("ann", 1.5)

    def test_union_requires_same_schema(self, people):
        u = people.union(people)
        assert u.num_rows == 8
        other = MLTable.from_rows([(1.0, 2.0)], num_partitions=1)
        with pytest.raises(TypeError):
            people.union(other)

    def test_filter(self, people):
        t = people.filter(lambda r: r.get("age") == 21)
        assert {r.get("name") for r in t.rows()} == {"bob", "dan"}

    def test_join(self, people):
        scores = MLTable.from_rows([("ann", "A"), ("bob", "B")],
                                   names=["name", "grade"], num_partitions=1)
        j = people.join(scores, on=["name"])
        assert j.num_rows == 2
        assert {r.get("grade") for r in j.rows()} == {"A", "B"}

    def test_num_rows_cols(self, people):
        assert people.num_rows == 4 and people.num_cols == 4


class TestMapReduceOps:
    def test_map(self, people):
        t = people.map(lambda r: (r.get("age") * 2,))
        assert [r[0] for r in t.rows()] == [68, 42, 90, 42]

    def test_flat_map(self, people):
        t = people.flat_map(lambda r: [(r.get("name"),)] * 2)
        assert t.num_rows == 8

    def test_reduce_is_partition_invariant(self):
        rows = [(float(i),) for i in range(10)]
        for parts in (1, 2, 3, 10):
            t = MLTable.from_rows(rows, num_partitions=parts)
            total = t.reduce(lambda a, b: (a[0] + b[0],))
            assert total[0] == 45.0

    def test_reduce_by_key(self, people):
        t = people.project(["age", "score"]).reduce_by_key(
            "age", lambda a, b: (a[0], a[1] + b[1]))
        by_age = {r[0]: r[1] for r in t.rows()}
        assert by_age[21] == 7.0 and by_age[34] == 1.5

    def test_empty_cells(self):
        schema = Schema.of(ColumnType.STRING, ColumnType.SCALAR)
        t = MLTable.from_rows([("a", 1.0), ("b", EMPTY)], schema=schema,
                              num_partitions=1)
        assert t.collect()[1].is_empty(1)


class TestToNumeric:
    def test_numeric_commit(self, people):
        nt = people.project(["age", "score"]).to_numeric(num_shards=2)
        assert nt.num_rows == 4 and nt.num_cols == 2
        np.testing.assert_allclose(np.asarray(nt.data)[:, 0], [34, 21, 45, 21])

    def test_non_numeric_rejected(self, people):
        with pytest.raises((TypeError, ValueError)):
            people.to_numeric()


class TestTextPipeline:
    """Fig. A2: textFile -> nGrams -> tfIdf."""

    def test_ngrams_tfidf(self):
        docs = ["the cat sat", "the dog sat", "the cat ran"]
        t = MLTable.from_text(docs, num_partitions=2)
        grams = n_grams(t, n=1, top=10)
        assert grams.num_rows == 3
        feat = tf_idf(grams)
        X = np.asarray(feat.to_numeric(num_shards=1).data)
        assert X.shape[0] == 3 and X.shape[1] <= 10
        # 'the' appears in every doc -> idf 0 -> column of zeros
        assert (X >= 0).all() and np.isfinite(X).all()


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(min_value=-100, max_value=100, allow_nan=False),
                min_size=1, max_size=30),
       st.integers(1, 6))
def test_reduce_partition_invariance_property(values, parts):
    t1 = MLTable.from_rows([(v,) for v in values], num_partitions=1)
    tp = MLTable.from_rows([(v,) for v in values], num_partitions=parts)
    r1 = t1.reduce(lambda a, b: (a[0] + b[0],))[0]
    rp = tp.reduce(lambda a, b: (a[0] + b[0],))[0]
    assert abs(r1 - rp) < 1e-6 * max(1.0, abs(r1))
