"""Property-testing front end: real hypothesis when installed, a small
deterministic fallback otherwise.

The test-suite's property tests (`@settings + @given` over integer / float /
list / sampled_from strategies) use hypothesis when the ``dev`` extra is
installed (``pip install -e .[dev]`` — what CI does).  On minimal
environments without hypothesis the fallback below runs each property with a
fixed number of deterministically-sampled examples, so the suite always
collects and the properties are still exercised — just without shrinking or
the full search heuristics.

Usage in test modules:

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    import inspect
    import random

    _MAX_FALLBACK_EXAMPLES = 10  # cap: no shrinking, so keep runtime bounded

    class _Strategy:
        """A draw function + repr; mirrors the tiny slice of the hypothesis
        strategy API the tests use."""

        def __init__(self, draw, name):
            self._draw = draw
            self._name = name

        def example(self, rng: random.Random):
            return self._draw(rng)

        def __repr__(self):
            return self._name

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=2**16):
            return _Strategy(lambda rng: rng.randint(min_value, max_value),
                             f"integers({min_value}, {max_value})")

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False,
                   allow_infinity=False):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                             f"floats({min_value}, {max_value})")

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements),
                             f"sampled_from({elements!r})")

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(size)]

            return _Strategy(draw, f"lists({elements!r})")

    st = _St()

    def settings(max_examples=None, deadline=None, **_ignored):
        """Record the example budget on the decorated test."""

        def decorate(fn):
            if max_examples is not None:
                fn._compat_max_examples = min(max_examples,
                                              _MAX_FALLBACK_EXAMPLES)
            return fn

        return decorate

    def given(*st_args, **st_kwargs):
        """Run the test once per deterministically-drawn example.

        Mirrors hypothesis's argument mapping: keyword strategies bind by
        name; positional strategies bind to the test's rightmost parameters
        (so methods keep ``self``).  The wrapper exposes only the unbound
        leading parameters to pytest (e.g. ``self`` or fixtures).
        """

        def decorate(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters)
            kw_bound = set(st_kwargs)
            pos_candidates = [p for p in params if p not in kw_bound]
            pos_bound = pos_candidates[len(pos_candidates) - len(st_args):]
            passthrough = [p for p in params
                           if p not in kw_bound and p not in pos_bound]

            def wrapper(*call_args, **call_kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            _MAX_FALLBACK_EXAMPLES)
                rng = random.Random(fn.__name__)
                for _ in range(n):
                    # bind drawn values by NAME: pytest passes fixtures as
                    # keywords, so positional insertion would shift onto the
                    # fixture parameters
                    drawn = {name: s.example(rng)
                             for name, s in zip(pos_bound, st_args)}
                    drawn.update((k, s.example(rng))
                                 for k, s in st_kwargs.items())
                    try:
                        fn(*call_args, **call_kwargs, **drawn)
                    except BaseException:
                        # what hypothesis would shrink and report: the drawn
                        # example (including any seed= strategy), so a CI
                        # failure is reproducible from the log alone
                        print(f"Falsifying example: "
                              f"{fn.__name__}(**{drawn!r})")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__signature__ = inspect.Signature(
                [sig.parameters[p] for p in passthrough])
            if hasattr(fn, "_compat_max_examples"):
                wrapper._compat_max_examples = fn._compat_max_examples
            return wrapper

        return decorate
