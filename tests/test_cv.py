"""Property tests for `tune/cv`: the splitter invariants model search
stands on.

  * folds are pairwise disjoint and cover every row exactly once;
  * fold sizes are balanced (differ by at most one row);
  * the assignment is a pure function of (num_rows, k, seed) — re-seeding
    with the same seed reproduces it exactly;
  * the resident-table view (`fold_view`) and the stream view
    (`BatchIterator.restrict`) select the same rows in the same order;
  * holdout splits obey the same cover/disjoint/determinism contract.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.numeric_table import MLNumericTable
from repro.data import BatchIterator
from repro.tune.cv import KFold, fold_view, holdout_split


@settings(max_examples=30, deadline=None)
@given(num_rows=st.integers(4, 200), k=st.integers(2, 8),
       seed=st.integers(0, 2**16))
def test_folds_disjoint_and_cover_exactly_once(num_rows, k, seed):
    k = min(k, num_rows)
    kf = KFold(num_rows, k, seed)
    seen = np.concatenate([kf.val_indices(i) for i in range(k)])
    # exact cover: every row in exactly one fold
    assert sorted(seen.tolist()) == list(range(num_rows))
    for i in range(k):
        tr, va = kf.split(i)
        assert np.intersect1d(tr, va).size == 0
        joined = np.sort(np.concatenate([tr, va]))
        assert np.array_equal(joined, np.arange(num_rows))
        # views preserve row order: indices are sorted
        assert np.array_equal(tr, np.sort(tr))
        assert np.array_equal(va, np.sort(va))


@settings(max_examples=30, deadline=None)
@given(num_rows=st.integers(4, 200), k=st.integers(2, 8),
       seed=st.integers(0, 2**16))
def test_fold_sizes_balanced(num_rows, k, seed):
    k = min(k, num_rows)
    sizes = [len(KFold(num_rows, k, seed).val_indices(i)) for i in range(k)]
    assert sum(sizes) == num_rows
    assert max(sizes) - min(sizes) <= 1


@settings(max_examples=20, deadline=None)
@given(num_rows=st.integers(4, 200), k=st.integers(2, 6),
       seed=st.integers(0, 2**16))
def test_folds_stable_under_reseeding(num_rows, k, seed):
    k = min(k, num_rows)
    a, b = KFold(num_rows, k, seed), KFold(num_rows, k, seed)
    for i in range(k):
        assert np.array_equal(a.val_indices(i), b.val_indices(i))
        assert np.array_equal(a.train_indices(i), b.train_indices(i))


@settings(max_examples=15, deadline=None)
@given(num_rows=st.integers(8, 96), k=st.integers(2, 4),
       seed=st.integers(0, 2**16))
def test_resident_and_stream_views_agree(num_rows, k, seed):
    """fold_view over a resident table and BatchIterator.restrict over a
    stream of the same rows must select identical data, row for row."""
    k = min(k, num_rows)
    rows = np.arange(num_rows * 3, dtype=np.float32).reshape(num_rows, 3)
    table = MLNumericTable.from_numpy(rows, num_shards=1)
    kf = KFold(num_rows, k, seed)
    for i in range(k):
        for idx in kf.split(i):
            resident = np.asarray(fold_view(table, idx).data)
            stream = BatchIterator(lambda step: {"data": rows}).restrict(idx)
            streamed = np.asarray(next(stream)["data"])
            np.testing.assert_array_equal(resident, streamed)
            np.testing.assert_array_equal(resident, rows[idx])


def test_fold_view_keeps_shards_when_divisible():
    rows = np.arange(64 * 2, dtype=np.float32).reshape(64, 2)
    table = MLNumericTable.from_numpy(rows, num_shards=4)
    view = fold_view(table, np.arange(16))          # 16 % 4 == 0
    assert view.num_shards == 4
    ragged = fold_view(table, np.arange(18))        # 18 % 4 != 0
    assert ragged.num_shards == 1


@settings(max_examples=20, deadline=None)
@given(num_rows=st.integers(4, 200), seed=st.integers(0, 2**16),
       frac=st.floats(0.1, 0.9))
def test_holdout_split_properties(num_rows, seed, frac):
    tr, va = holdout_split(num_rows, frac, seed)
    assert np.intersect1d(tr, va).size == 0
    assert np.array_equal(np.sort(np.concatenate([tr, va])),
                          np.arange(num_rows))
    assert len(va) >= 1 and len(tr) >= 1
    tr2, va2 = holdout_split(num_rows, frac, seed)
    assert np.array_equal(tr, tr2) and np.array_equal(va, va2)


def test_restrict_passes_through_short_values():
    """Per-window broadcast extras (leading dim too short to index) ride
    through a restricted stream untouched."""
    rows = np.arange(20, dtype=np.float32).reshape(10, 2)
    extra = np.asarray([1.0, 2.0])
    stream = BatchIterator(lambda step: {"data": rows, "extra": extra})
    out = next(stream.restrict(np.asarray([7, 8, 9])))
    np.testing.assert_array_equal(np.asarray(out["data"]), rows[[7, 8, 9]])
    np.testing.assert_array_equal(np.asarray(out["extra"]), extra)


def test_restrict_refuses_non_covering_window():
    """A window too short for the fold indices must raise, never silently
    skip the restriction (that would leak validation rows into training)."""
    short = np.arange(16, dtype=np.float32).reshape(8, 2)
    stream = BatchIterator(lambda step: {"data": short})
    restricted = stream.restrict(np.asarray([0, 3, 12]))  # needs 13 rows
    with pytest.raises(ValueError, match="cannot cover"):
        next(restricted)
    # even when SOME other value covers, a too-short 'data' must raise
    mixed = BatchIterator(
        lambda step: {"data": short, "mask": np.ones(32, np.float32)})
    with pytest.raises(ValueError, match="'data' window"):
        next(mixed.restrict(np.asarray([0, 3, 12])))
    with pytest.raises(ValueError, match="zero rows"):
        stream.restrict(np.asarray([], dtype=np.int64))


def test_kfold_validates_arguments():
    with pytest.raises(ValueError):
        KFold(10, 1)
    with pytest.raises(ValueError):
        KFold(4, 8)
    with pytest.raises(ValueError):
        KFold(10, 3).val_indices(3)
    with pytest.raises(ValueError):
        holdout_split(10, 0.0)
