"""ShardLint mutation tests: every analyzer rule must trip on its seeded
violation and stay silent on the known-good twin.

Three tiers:

* lint rules — AST fixtures under ``tests/analysis_fixtures/``: for each
  rule one MUST-FLAG file and one MUST-PASS file (the mutation test of
  the analyzer itself);
* jaxpr-audit rules — deliberate violations built in-process (a
  ``debug_callback`` in a jitted body, an f64 promotion under
  ``enable_x64``, an un-donated large carry, a collective on an
  undeclared axis) and asserted detected;
* retrace sentinel — a cold jit must trip ``assert_no_retrace``, a
  warmed one must not; plus the fast (emulated) twin of the stacked
  rung-segment compile-once contract.

The registered-manifest audit itself must also be green — the same
invocation CI runs.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (AuditSpec, RetraceError, assert_no_retrace,
                            audit_jaxpr, hot_paths, lint_file, lint_source,
                            run_audit, watch_compiles)

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")

# rule id -> (must-flag fixture, must-pass fixture, store_rules)
LINT_CASES = {
    "traced-leak": ("traced_leak_bad.py", "traced_leak_good.py", False),
    "wallclock-in-trace": ("wallclock_bad.py", "wallclock_good.py", False),
    "donated-reuse": ("donated_reuse_bad.py", "donated_reuse_good.py", False),
    "non-atomic-write": ("atomic_write_bad.py", "atomic_write_good.py", True),
    "jit-in-loop": ("jit_in_loop_bad.py", "jit_in_loop_good.py", True),
}


class TestLintRules:
    @pytest.mark.parametrize("rule", sorted(LINT_CASES))
    def test_must_flag(self, rule):
        bad, _, store = LINT_CASES[rule]
        findings = lint_file(os.path.join(FIXTURES, bad), store_rules=store)
        assert any(f.rule == rule for f in findings), (
            f"{bad} seeded a {rule} violation but the rule stayed silent: "
            f"{findings}")

    @pytest.mark.parametrize("rule", sorted(LINT_CASES))
    def test_must_pass(self, rule):
        _, good, store = LINT_CASES[rule]
        findings = lint_file(os.path.join(FIXTURES, good), store_rules=store)
        hits = [f for f in findings if f.rule == rule]
        assert not hits, f"{good} is known-good for {rule} but flagged: {hits}"

    def test_flag_counts_are_exact(self):
        """Every seeded violation is found — not just 'at least one'."""
        findings = lint_file(os.path.join(FIXTURES, "traced_leak_bad.py"),
                             store_rules=False)
        assert sum(f.rule == "traced-leak" for f in findings) == 4
        findings = lint_file(os.path.join(FIXTURES, "wallclock_bad.py"),
                             store_rules=False)
        assert sum(f.rule == "wallclock-in-trace" for f in findings) == 3
        findings = lint_file(os.path.join(FIXTURES, "atomic_write_bad.py"),
                             store_rules=True)
        assert sum(f.rule == "non-atomic-write" for f in findings) == 3

    def test_allowlist_comment_suppresses(self):
        src = ("import jax\n"
               "def f(xs):\n"
               "    for x in xs:\n"
               "        # lint: allow[jit-in-loop] one-off trace for a test\n"
               "        g = jax.jit(lambda v: v + x)\n"
               "    return g\n")
        assert lint_source(src, "allowed.py") == []
        # without the comment the same source flags
        stripped = src.replace(
            "        # lint: allow[jit-in-loop] one-off trace for a test\n",
            "")
        assert any(f.rule == "jit-in-loop"
                   for f in lint_source(stripped, "bare.py"))

    def test_store_rules_scoped_by_path(self):
        src = 'def f(p, d):\n    with open(p, "w") as fh:\n        fh.write(d)\n'
        assert any(f.rule == "non-atomic-write"
                   for f in lint_source(src, "src/repro/checkpoint/x.py"))
        assert lint_source(src, "src/repro/eval/x.py") == []

    def test_syntax_error_is_reported_not_raised(self):
        findings = lint_source("def broken(:\n", "broken.py")
        assert [f.rule for f in findings] == ["syntax-error"]


class TestJaxprAudit:
    def test_host_callback_detected(self):
        @jax.jit
        def noisy(x):
            jax.debug.print("x={x}", x=x)
            return x * 2

        closed = jax.make_jaxpr(lambda: noisy(jnp.ones(4)))()
        findings = audit_jaxpr(closed, AuditSpec(), where="t")
        assert any(f.rule == "host-callback" for f in findings)
        # the same path with one declared callback passes
        assert audit_jaxpr(closed, AuditSpec(allow_callbacks=1),
                           where="t") == []

    def test_f64_promotion_detected(self):
        with jax.experimental.enable_x64():
            def promoting(x):
                return x.astype(jnp.float64).sum()

            closed = jax.make_jaxpr(
                lambda: promoting(jnp.ones(4, jnp.float32)))()
        findings = audit_jaxpr(closed, AuditSpec(), where="t")
        assert any(f.rule == "f64-promotion" for f in findings)
        assert audit_jaxpr(closed, AuditSpec(allow_f64=True), where="t") == []

    def test_non_donated_carry_detected(self):
        big = jnp.ones((64, 64), jnp.float32)   # 16 KiB

        @jax.jit
        def undonated_step(state):
            return state * 2

        closed = jax.make_jaxpr(lambda: undonated_step(big))()
        findings = audit_jaxpr(
            closed, AuditSpec(expect_donation=("undonated_step",)),
            where="t")
        assert any(f.rule == "non-donated-carry" for f in findings)

        from functools import partial

        @partial(jax.jit, donate_argnums=(0,))
        def donated_step(state):
            return state * 2

        closed = jax.make_jaxpr(lambda: donated_step(jnp.copy(big)))()
        assert audit_jaxpr(
            closed, AuditSpec(expect_donation=("donated_step",)),
            where="t") == []

    def test_missing_expected_jit_detected(self):
        closed = jax.make_jaxpr(lambda: jnp.ones(3) * 2)()
        findings = audit_jaxpr(closed, AuditSpec(expect_donation=("epoch",)),
                               where="t")
        assert any(f.rule == "non-donated-carry" and "no such pjit" in f.message
                   for f in findings)

    def test_collective_axis_mismatch_detected(self):
        from jax.sharding import PartitionSpec as P

        from repro.core.compat import make_mesh, shard_map

        mesh = make_mesh((1,), ("rogue",))

        def summed(x):
            return shard_map(lambda b: jax.lax.psum(b, "rogue"), mesh=mesh,
                             in_specs=P("rogue"), out_specs=P())(x)

        closed = jax.make_jaxpr(lambda: summed(jnp.ones(4)))()
        findings = audit_jaxpr(
            closed, AuditSpec(declared_axes=frozenset({"data"})), where="t")
        assert any(f.rule == "collective-axis" and "rogue" in f.message
                   for f in findings)
        # with the axis declared, the same jaxpr passes
        assert audit_jaxpr(
            closed, AuditSpec(declared_axes=frozenset({"rogue"})),
            where="t") == []

    def test_registered_manifest_is_green(self):
        """The CI leg's exact contract: every auditable hot path clean."""
        findings, audited, _ = run_audit()
        assert findings == [], findings
        assert len(audited) >= 6
        assert len(hot_paths()) >= 8


class TestRetraceSentinel:
    def test_cold_jit_trips(self):
        @jax.jit
        def f(x):
            return x + 1

        with pytest.raises(RetraceError, match="observed"):
            with assert_no_retrace("cold call"):
                f(jnp.ones(7))

    def test_warm_jit_passes_and_watch_counts(self):
        @jax.jit
        def f(x):
            return x + 1

        with watch_compiles() as w:
            f(jnp.ones(8))
        assert w.compiles >= 1
        # input built outside the guard: only f's dispatch is under watch
        x2 = jnp.ones(8) * 3
        with assert_no_retrace("warmed call"):
            f(x2)

    def test_allowance(self):
        @jax.jit
        def f(x):
            return x * 2

        x = jnp.ones(9)
        with assert_no_retrace("declared one-off", allow=2):
            f(x)

    def test_shape_drift_is_caught(self):
        @jax.jit
        def f(x):
            return x.sum()

        f(jnp.ones(4))
        with pytest.raises(RetraceError):
            with assert_no_retrace("drifted shape"):
                f(jnp.ones(5))

    def test_stacked_segments_compile_once_emulated(self):
        """Fast twin of the mesh determinism check: after the first rung
        segment, later segments (new start_epoch / active / offsets) ride
        the SAME compiled epoch — the PR-3 claim as an assert."""
        from repro.core.optimizer import sgd_trial_round
        from repro.core.runner import DistributedRunner

        k, d = 4, 8
        runner = DistributedRunner(num_shards=4)
        grad = lambda vec, w, hyper: (vec[1:] @ w - vec[0]) * vec[1:]
        step = sgd_trial_round(grad, local_batch_size=4)
        hyper = {"lr": jnp.full((k,), 0.1, jnp.float32),
                 "decay": jnp.ones((k,), jnp.float32),
                 "l1": jnp.zeros((k,), jnp.float32)}
        rng = np.random.default_rng(0)
        win = jnp.asarray(rng.normal(size=(64, d + 1)).astype(np.float32))
        stream = iter(lambda: {"data": win}, None)
        trials = jnp.zeros((k, d), jnp.float32)
        act2 = jnp.asarray([True, False, True, True])
        offs = jnp.asarray([0, 0, 4, 0], jnp.int32)

        warm = runner.run_stacked_epochs(stream, trials, hyper, step, 1,
                                         chunks_per_epoch=4)
        with assert_no_retrace("rung segments after the first"):
            seg2 = runner.run_stacked_epochs(
                stream, warm, hyper, step, 2, start_epoch=1, active=act2,
                chunks_per_epoch=4)
            runner.run_stacked_epochs(
                stream, seg2, hyper, step, 3, start_epoch=2, active=act2,
                round_offsets=offs, chunks_per_epoch=4)
