"""Callback protocol: dispatch, built-ins, and the runner/search hook points.

The contract under test (``repro.tune.callback``): callbacks are host-side
hooks fired between compiled epochs — ordering by ``cb.order``, the
before/after split, carry swaps folding into the env later callbacks see,
:class:`EarlyStopException` ending the loop with the tail checkpoint still
written, and replay idempotence (a resumed run re-firing boundaries it
already fired must not change what observers recorded).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.runner import CheckpointPolicy, DistributedRunner
from repro.data import BatchIterator
from repro.eval.metrics import MetricHistory
from repro.tune.callback import (CallbackEnv, EarlyStopException, EvalEntry,
                                 early_stopping, fire_callbacks,
                                 hyper_schedule, record_evaluation,
                                 split_callbacks)


def env_with(evals=(), **kw):
    return CallbackEnv(epoch=kw.pop("epoch", 1), evals=tuple(evals), **kw)


# --------------------------------------------------------------------------- #
# dispatch: ordering, before/after split, swap folding
# --------------------------------------------------------------------------- #
def test_split_orders_and_partitions():
    def mk(name, order=10, before=False):
        def cb(env):
            return None
        cb.__name__ = name
        cb.order = order
        cb.before_epoch = before
        return cb

    a = mk("a", order=30)
    b = mk("b", order=0)
    c = mk("c")                       # default order 10, after
    d = mk("d", order=5, before=True)
    e = mk("e", order=1, before=True)
    before, after = split_callbacks([a, b, c, d, e])
    assert [cb.__name__ for cb in before] == ["e", "d"]
    assert [cb.__name__ for cb in after] == ["b", "c", "a"]


def test_equal_order_keeps_registration_order():
    seen = []

    def mk(tag):
        def cb(env):
            seen.append(tag)
        return cb  # no .order attr: both default to 10

    _, after = split_callbacks([mk("first"), mk("second")])
    fire_callbacks(after, env_with())
    assert seen == ["first", "second"]


def test_fire_folds_swaps_into_later_envs():
    def steer(env):
        return {"hyper": {"lr": 99.0}}

    seen = {}

    def observe(env):
        seen["hyper"] = env.hyper

    steer.order = 0
    observe.order = 10
    swaps = fire_callbacks((steer, observe), env_with(hyper={"lr": 1.0}))
    assert swaps == {"hyper": {"lr": 99.0}}
    assert seen["hyper"] == {"lr": 99.0}  # later callback saw the swap


def test_fire_refuses_unknown_swap_keys():
    def bad(env):
        return {"optimizer": object()}

    with pytest.raises(ValueError, match="unknown carry keys"):
        fire_callbacks((bad,), env_with())


# --------------------------------------------------------------------------- #
# built-ins
# --------------------------------------------------------------------------- #
def test_early_stopping_counts_stalls_and_raises():
    cb = early_stopping(stopping_rounds=2)
    cb(env_with([EvalEntry(0, "acc", 0.5)], epoch=1))      # baseline
    cb(env_with([EvalEntry(0, "acc", 0.7)], epoch=2))      # improves
    cb(env_with([EvalEntry(0, "acc", 0.7)], epoch=3))      # stall 1
    with pytest.raises(EarlyStopException) as err:
        cb(env_with([EvalEntry(0, "acc", 0.6)], epoch=4))  # stall 2
    assert err.value.epoch == 4
    assert cb.best[(0, "acc")] == 0.7


def test_early_stopping_direction_and_min_delta():
    # lower-is-better metric: decreasing values are improvements
    cb = early_stopping(stopping_rounds=1, min_delta=0.05)
    cb(env_with([EvalEntry(0, "loss", 1.0, False)], epoch=1))
    cb(env_with([EvalEntry(0, "loss", 0.5, False)], epoch=2))   # big gain
    with pytest.raises(EarlyStopException):
        # a 0.01 gain is below min_delta — counts as a stall
        cb(env_with([EvalEntry(0, "loss", 0.49, False)], epoch=3))
    # the sub-delta gain still updated the tracked best
    assert cb.best[(0, "loss")] == 0.49


def test_early_stopping_ignores_hookpoints_without_evals():
    cb = early_stopping(stopping_rounds=1)
    cb(env_with([EvalEntry(0, "acc", 0.5)], epoch=1))
    for epoch in range(2, 10):
        cb(env_with([], epoch=epoch))  # no evidence — no stall counted
    cb(env_with([EvalEntry(0, "acc", 0.9)], epoch=10))


def test_early_stopping_any_trial_improvement_resets_the_stall():
    cb = early_stopping(stopping_rounds=2)
    both = [EvalEntry(0, "acc", 0.5), EvalEntry(1, "acc", 0.4)]
    cb(env_with(both, epoch=1))
    # trial 0 stalls but trial 1 improves: not a stalled hook point
    cb(env_with([EvalEntry(0, "acc", 0.5), EvalEntry(1, "acc", 0.6)], epoch=2))
    cb(env_with(both, epoch=3))
    with pytest.raises(EarlyStopException):
        cb(env_with(both, epoch=4))


def test_record_evaluation_overwrites_on_replay():
    hist = MetricHistory()
    cb = record_evaluation(hist)
    cb(env_with([EvalEntry(0, "acc", 0.5)], epoch=1))
    cb(env_with([EvalEntry(0, "acc", 0.8)], epoch=2))
    before = hist.to_dict()
    # a resumed run replays the epoch-1 boundary it already recorded
    cb(env_with([EvalEntry(0, "acc", 0.5)], epoch=1))
    assert hist.to_dict() == before
    assert hist.series(0, "acc") == [(1, 0.5), (2, 0.8)]
    assert hist.last(0, "acc") == 0.8


def test_record_evaluation_requires_a_recorder():
    with pytest.raises(TypeError, match="record"):
        record_evaluation([])


def test_hyper_schedule_swaps_param_and_checks_names():
    cb = hyper_schedule("lr", lambda e: 0.1 * (e + 1))
    assert cb.before_epoch and cb.order == 0
    out = cb(env_with(hyper={"lr": jnp.full((3,), 9.0)}, epoch=4))
    np.testing.assert_allclose(np.asarray(out["hyper"]["lr"]),
                               np.full(3, 0.5, np.float32))
    assert cb(env_with(hyper=None)) is None        # plain loops: no-op
    with pytest.raises(KeyError, match="momentum"):
        hyper_schedule("momentum", lambda e: 0.0)(
            env_with(hyper={"lr": jnp.ones(())}))


# --------------------------------------------------------------------------- #
# runner hook points (emulated partitions — host-side behavior under test)
# --------------------------------------------------------------------------- #
def _const_stream(X):
    return BatchIterator(lambda step: {"data": X})


def test_run_epochs_firing_order_and_epoch_counters(rng):
    X = np.asarray(rng.normal(size=(8, 2)), np.float32)
    runner = DistributedRunner(num_shards=2)
    fired = []

    def before(env):
        fired.append(("before", env.epoch))
    before.before_epoch = True

    def after(env):
        fired.append(("after", env.epoch))

    runner.run_epochs(_const_stream(X), jnp.zeros(2),
                      lambda b, s, r: s + jnp.mean(b, 0), 3,
                      callbacks=[before, after])
    assert fired == [("before", 0), ("after", 1), ("before", 1), ("after", 2),
                     ("before", 2), ("after", 3)]


def test_run_epochs_early_stop_returns_partial_state_and_tail_checkpoint(
        rng, tmp_path):
    from repro.checkpoint import latest_step

    X = np.asarray(rng.normal(size=(8, 2)), np.float32)
    runner = DistributedRunner(num_shards=2)
    step = lambda b, s, r: s + jnp.mean(b, 0)

    def stop_after_two(env):
        if env.epoch >= 2:
            raise EarlyStopException(env.epoch, "test stop")

    want = runner.run_epochs(_const_stream(X), jnp.zeros(2), step, 2)
    got = runner.run_epochs(
        _const_stream(X), jnp.zeros(2), step, 10,
        callbacks=[stop_after_two],
        checkpoint=CheckpointPolicy(str(tmp_path), every_epochs=100))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the tail snapshot lands at the stop epoch, not the planned horizon
    assert latest_step(str(tmp_path)) == 2


def test_run_epochs_eval_fn_feeds_callbacks(rng):
    X = np.asarray(rng.normal(size=(8, 2)), np.float32)
    runner = DistributedRunner(num_shards=2)
    hist = MetricHistory()
    runner.run_epochs(
        _const_stream(X), jnp.zeros(2),
        lambda b, s, r: s + jnp.mean(b, 0), 3,
        callbacks=[record_evaluation(hist)],
        eval_fn=lambda state, epoch: [EvalEntry(0, "norm",
                                                float(jnp.sum(state ** 2)))])
    assert [e for e, _ in hist.series(0, "norm")] == [1, 2, 3]
    # the recorded trajectory is monotone for this accumulating step
    values = [v for _, v in hist.series(0, "norm")]
    assert values == sorted(values)


def test_run_epochs_early_stopping_on_plateau_metric(rng):
    """End-to-end built-in: an eval that plateaus after epoch 2 trips
    early_stopping(2) at epoch 4 of a 10-epoch budget."""
    X = np.asarray(rng.normal(size=(8, 2)), np.float32)
    runner = DistributedRunner(num_shards=2)
    fired = []

    def plateau_eval(state, epoch):
        fired.append(epoch)
        return [EvalEntry(0, "score", float(min(epoch, 2)))]

    runner.run_epochs(_const_stream(X), jnp.zeros(2),
                      lambda b, s, r: s + jnp.mean(b, 0), 10,
                      callbacks=[early_stopping(2)], eval_fn=plateau_eval)
    assert fired == [1, 2, 3, 4]  # baseline, improve, stall, stall -> stop


def test_run_epochs_hyper_swap_requires_hyper_tree(rng):
    """run_epochs has no hyper carry: a callback returning a hyper swap is
    refused loudly instead of silently dropped."""
    X = np.asarray(rng.normal(size=(8, 2)), np.float32)
    runner = DistributedRunner(num_shards=2)

    def bad(env):
        return {"hyper": {"lr": 0.0}}

    with pytest.raises(ValueError, match="hyper"):
        runner.run_epochs(_const_stream(X), jnp.zeros(2),
                          lambda b, s, r: s + jnp.mean(b, 0), 2,
                          callbacks=[bad])


def test_run_epochs_state_swap_changes_the_carry(rng):
    X = np.asarray(rng.normal(size=(8, 2)), np.float32)
    runner = DistributedRunner(num_shards=2)
    step = lambda b, s, r: s + jnp.mean(b, 0)

    def reset_at_two(env):
        if env.epoch == 2:
            return {"state": jnp.zeros(2)}
    # resetting the carry at epoch 2 of 4 == running the last 2 epochs
    got = runner.run_epochs(_const_stream(X), jnp.zeros(2), step, 4,
                            callbacks=[reset_at_two])
    want = runner.run_epochs(
        BatchIterator(lambda step_no: {"data": X}, start_step=2),
        jnp.zeros(2), step, 4, start_epoch=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------- #
# stacked hook points: the shim presents trial-level envs
# --------------------------------------------------------------------------- #
def test_stacked_hyper_schedule_steers_all_lanes(rng):
    """lr-schedule fn(epoch)=0 freezes every lane: the stacked loop with
    the schedule must end exactly at its initial states."""
    from repro.tune.trials import tree_stack

    X = np.asarray(rng.normal(size=(8, 2)), np.float32)
    runner = DistributedRunner(num_shards=2)

    def trial_step(block, s, r, hyper):
        return hyper["lr"] * jnp.mean(block, 0) + 0 * s

    def trial_update(s, c, r, hyper):
        return s + c

    init = tree_stack([jnp.zeros(2), jnp.ones(2)])
    hyper = tree_stack([{"lr": jnp.float32(1.0)}, {"lr": jnp.float32(2.0)}])
    frozen = runner.run_stacked_epochs(
        _const_stream(X), init, hyper, trial_step, 3, update=trial_update,
        callbacks=[hyper_schedule("lr", lambda e: 0.0)])
    np.testing.assert_array_equal(np.asarray(frozen), np.asarray(init))
    # without the schedule the states move — the schedule was load-bearing
    moved = runner.run_stacked_epochs(
        _const_stream(X), init, hyper, trial_step, 3, update=trial_update)
    assert not np.allclose(np.asarray(moved), np.asarray(init))


def test_stacked_callbacks_see_active_mask_and_stop(rng):
    from repro.tune.trials import tree_stack

    X = np.asarray(rng.normal(size=(8, 2)), np.float32)
    runner = DistributedRunner(num_shards=2)
    seen = []

    def watch(env):
        seen.append((env.epoch, tuple(np.asarray(env.active))))
        if env.epoch == 2:
            raise EarlyStopException(env.epoch, "enough")

    init = tree_stack([jnp.zeros(2), jnp.ones(2)])
    hyper = tree_stack([{"lr": jnp.float32(1.0)}, {"lr": jnp.float32(1.0)}])
    runner.run_stacked_epochs(
        _const_stream(X), init, hyper,
        lambda b, s, r, h: h["lr"] * jnp.mean(b, 0) + 0 * s, 5,
        update=lambda s, c, r, h: s + c,
        active=jnp.asarray([True, False]), callbacks=[watch])
    assert seen == [(1, (True, False)), (2, (True, False))]
