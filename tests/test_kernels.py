"""Per-kernel allclose vs the pure-jnp oracle (interpret=True on CPU).
Sweeps shapes, dtypes, and mask variants per the deliverable contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.logreg_grad import logreg_grad_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _tol(dtype):
    return dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,KV,Sq,Sk,hd", [
        (1, 4, 4, 128, 128, 64),      # MHA square
        (2, 4, 2, 128, 128, 64),      # GQA
        (1, 8, 1, 128, 512, 128),     # MQA rectangular (decode-ish)
        (2, 2, 2, 256, 256, 32),      # small head dim
    ])
    def test_causal_sweep(self, B, H, KV, Sq, Sk, hd, dtype):
        q = _rand((B, H, Sq, hd), dtype)
        k = _rand((B, KV, Sk, hd), dtype)
        v = _rand((B, KV, Sk, hd), dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("mask_kw", [
        dict(causal=False),
        dict(causal=True, window=100),
        dict(causal=True, window=128),
        dict(causal=True, chunk=128),
        dict(causal=True, chunk=256),
    ])
    def test_mask_variants(self, mask_kw):
        q = _rand((1, 4, 256, 64), jnp.float32)
        k = _rand((1, 2, 256, 64), jnp.float32)
        v = _rand((1, 2, 256, 64), jnp.float32)
        out = flash_attention(q, k, v, interpret=True, **mask_kw)
        expect = ref.flash_attention_ref(q, k, v, **mask_kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_block_shape_independence(self):
        """Different VMEM tilings must give identical results."""
        q = _rand((1, 2, 256, 64), jnp.float32)
        k = _rand((1, 2, 256, 64), jnp.float32)
        v = _rand((1, 2, 256, 64), jnp.float32)
        outs = [np.asarray(flash_attention(q, k, v, causal=True, block_q=bq,
                                           block_k=bk, interpret=True))
                for bq, bk in [(128, 128), (64, 128), (128, 64), (256, 256)]]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)

    def test_fully_masked_rows_are_zero(self):
        """Rows whose window admits no keys must not NaN (0/denom guard)."""
        q = _rand((1, 1, 128, 64), jnp.float32)
        k = _rand((1, 1, 128, 64), jnp.float32)
        v = _rand((1, 1, 128, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=1, interpret=True)
        assert bool(jnp.isfinite(out).all())


class TestLogregGrad:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n,d", [(256, 512), (512, 1024), (1024, 512),
                                     (256, 2048)])
    def test_sweep(self, n, d, dtype):
        X = _rand((n, d), dtype)
        y = jnp.asarray(RNG.integers(0, 2, size=n), jnp.float32)
        w = (_rand((d,), dtype) * 0.05).astype(dtype)
        got = logreg_grad_pallas(X, y, w, interpret=True)
        expect = ref.logreg_grad_ref(X, y, w)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(expect, np.float32),
                                   rtol=5e-2 if dtype == jnp.bfloat16 else 2e-3,
                                   atol=5e-1 if dtype == jnp.bfloat16 else 5e-2)

    def test_block_shape_independence(self):
        X = _rand((512, 1024), jnp.float32)
        y = jnp.asarray(RNG.integers(0, 2, size=512), jnp.float32)
        w = _rand((1024,), jnp.float32) * 0.05
        outs = [np.asarray(logreg_grad_pallas(X, y, w, block_rows=br,
                                              block_cols=bc, interpret=True))
                for br, bc in [(256, 512), (128, 256), (512, 1024)]]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-3)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(4, 33, 256), (128, 1024), (2, 7, 8, 512)])
    def test_sweep(self, shape, dtype):
        x = _rand(shape, dtype)
        w = _rand((shape[-1],), dtype)
        got = rmsnorm_pallas(x, w, interpret=True)
        expect = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(expect, np.float32), **_tol(dtype))

    def test_scale_invariance_of_direction(self):
        """rmsnorm(c·x) == rmsnorm(x) for c>0 — the defining invariant."""
        x = _rand((8, 128), jnp.float32)
        w = jnp.ones((128,), jnp.float32)
        a = rmsnorm_pallas(x, w, interpret=True)
        b = rmsnorm_pallas(x * 7.3, w, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


class TestKMeansAssign:
    """Fused pairwise-distance assignment vs its oracle — the oracle uses
    the identical expanded form (||c||² − 2·x·c), so the comparison is
    exact fp parity, not just same-argmin on separated data."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n,d,k", [
        (256, 512, 8),        # single tile
        (512, 1024, 5),       # multi-tile both axes, odd k
        (256, 512, 16),
    ])
    def test_sweep(self, n, d, k, dtype):
        X = _rand((n, d), dtype)
        C = _rand((k, d), dtype)
        got = kmeans_assign_pallas(X, C, interpret=True)
        want = ref.kmeans_assign_ref(X, C)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_full_distance_argmin(self):
        """The expanded form must produce the same assignment as the naive
        (n, k, d) broadcast argmin on generic float data."""
        X = _rand((256, 512), jnp.float32)
        C = _rand((6, 512), jnp.float32)
        got = kmeans_assign_pallas(X, C, interpret=True)
        d2 = jnp.sum((X[:, None, :] - C[None, :, :]) ** 2, axis=-1)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.argmin(d2, axis=-1)))

    def test_block_shape_independence(self):
        X = _rand((512, 1024), jnp.float32)
        C = _rand((8, 1024), jnp.float32)
        a = kmeans_assign_pallas(X, C, block_rows=256, block_cols=512,
                                 interpret=True)
        b = kmeans_assign_pallas(X, C, block_rows=128, block_cols=256,
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_tie_breaks_to_lowest_index(self):
        """Duplicate centroids: the fused argmin must keep jnp.argmin's
        first-wins tie rule (the manual iota/min reduction inside the
        kernel exists exactly for this)."""
        X = _rand((256, 512), jnp.float32)
        C0 = _rand((4, 512), jnp.float32)
        C = jnp.concatenate([C0, C0], axis=0)        # every row ties 2-way
        got = kmeans_assign_pallas(X, C, interpret=True)
        want = ref.kmeans_assign_ref(X, C)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(jnp.max(got)) < 4                 # always the first copy

    def test_routed_training_matches_oracle_path(self):
        """KMeansParameters(use_kernel=True) must train bitwise-identical
        centroids to the default path (same assignments → same sums)."""
        from repro.core.algorithms.kmeans import KMeans, KMeansParameters
        from repro.core.numeric_table import MLNumericTable

        rng = np.random.default_rng(0)
        X = (rng.normal(size=(128, 16)).astype(np.float32)
             + np.repeat(np.eye(4, 16, dtype=np.float32) * 6.0, 32, axis=0))
        table = MLNumericTable.from_numpy(X, num_shards=2)
        base = KMeans.train(table, KMeansParameters(k=4, max_iter=5))
        fused = KMeans.train(table, KMeansParameters(k=4, max_iter=5,
                                                     use_kernel=True))
        np.testing.assert_array_equal(np.asarray(base.centroids),
                                      np.asarray(fused.centroids))
        np.testing.assert_array_equal(
            np.asarray(base.predict(jnp.asarray(X))),
            np.asarray(fused.predict(jnp.asarray(X))))


class TestOpsWrappers:
    def test_fallback_on_indivisible_shapes(self):
        from repro.kernels import ops
        q = _rand((1, 2, 100, 64), jnp.float32)   # 100 not divisible by 128
        k = _rand((1, 2, 100, 64), jnp.float32)
        v = _rand((1, 2, 100, 64), jnp.float32)
        out = ops.flash_attention(q, k, v)
        expect = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)

    def test_shape_validation(self):
        from repro.kernels import ops
        with pytest.raises(ValueError):
            ops.logreg_grad(jnp.zeros((4, 4)), jnp.zeros((5,)), jnp.zeros((4,)))
        with pytest.raises(ValueError):
            ops.rmsnorm(jnp.zeros((4, 8)), jnp.zeros((9,)))
        with pytest.raises(ValueError):
            ops.kmeans_assign(jnp.zeros((8, 4)), jnp.zeros((2, 5)))

    def test_kmeans_assign_fallback_on_indivisible_shapes(self):
        from repro.kernels import ops
        X = _rand((37, 9), jnp.float32)              # tiles nothing
        C = _rand((3, 9), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(ops.kmeans_assign(X, C)),
            np.asarray(ref.kmeans_assign_ref(X, C)))


class TestSSDChunkScan:
    def _inputs(self, B=2, H=3, S=256, P=16, N=32, seed=0):
        rng = np.random.default_rng(seed)
        log_a = jnp.asarray(-np.abs(rng.normal(size=(B, H, S))) * 0.1,
                            jnp.float32)
        dx = jnp.asarray(rng.normal(size=(B, H, S, P)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
        h0 = jnp.asarray(rng.normal(size=(B, H, P, N)) * 0.1, jnp.float32)
        return log_a, dx, Bm, Cm, h0

    @pytest.mark.parametrize("chunk", [32, 64, 128])
    @pytest.mark.parametrize("shape", [(1, 2, 128, 8, 16), (2, 3, 256, 16, 32)])
    def test_sweep(self, shape, chunk):
        from repro.kernels.ssd_scan import ssd_chunk_scan
        B, H, S, P, N = shape
        log_a, dx, Bm, Cm, h0 = self._inputs(B, H, S, P, N)
        y, h = ssd_chunk_scan(log_a, dx, Bm, Cm, h0, chunk=chunk,
                              interpret=True)
        yr, hr = ref.ssd_chunk_scan_ref(log_a, dx, Bm, Cm, h0, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                                   rtol=1e-4, atol=1e-4)

    def test_chunk_size_independence(self):
        """Different VMEM chunk tilings must agree (the scan is exact)."""
        from repro.kernels.ssd_scan import ssd_chunk_scan
        log_a, dx, Bm, Cm, h0 = self._inputs()
        outs = [np.asarray(ssd_chunk_scan(log_a, dx, Bm, Cm, h0, chunk=c,
                                          interpret=True)[0])
                for c in (32, 64, 256)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-3, atol=1e-3)

    def test_zero_decay_is_cumulative_outer_products(self):
        """With a ≡ 1 (log_a = 0) the SSD state is a plain running sum of
        dx⊗B, and y_t = C_t · Σ_{s≤t} dx_s⊗B_s — an analytic invariant."""
        from repro.kernels.ssd_scan import ssd_chunk_scan
        rng = np.random.default_rng(1)
        B, H, S, P, N = 1, 1, 64, 4, 8
        log_a = jnp.zeros((B, H, S), jnp.float32)
        dx = jnp.asarray(rng.normal(size=(B, H, S, P)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        y, h = ssd_chunk_scan(log_a, dx, Bm, Cm, chunk=16, interpret=True)
        run = np.zeros((P, N))
        for t in range(S):
            run = run + np.outer(np.asarray(dx[0, 0, t]), np.asarray(Bm[0, t]))
            expect = run @ np.asarray(Cm[0, t])
            np.testing.assert_allclose(np.asarray(y[0, 0, t]), expect,
                                       rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(h[0, 0]), run, rtol=1e-3,
                                   atol=1e-3)
