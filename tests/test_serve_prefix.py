"""Serving-path tests for the radix prefix KV cache: greedy streams must
be BIT-IDENTICAL cache-on vs cache-off across ragged, sliding-window, and
weight-quantized (int8/bf16) paths — restored blocks are the bits a full
prefill wrote, so there is no tolerance, only equality.  Also pins the
engine's refusals (int8 KV storage, non-ragged stacks), warmup hygiene
(probe blocks dropped), the fleet-shared trie, and the predictor's
featurize memo (the classical-model twin of prefix caching)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.transformer import init_model
from repro.serve import (ModelPredictor, PredictRequest, RadixPrefixCache,
                         ReplicaRouter, Request, ServeEngine)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2-1.5b")                    # dense GQA, global attn
    params, _ = init_model(KEY, cfg)
    return cfg, params


@pytest.fixture(scope="module")
def gemma():
    cfg = get_smoke("gemma3-1b")                     # sliding-window rings
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _shared_trace(cfg, *, prefix_len=24, n=6, lead_with_prefix=False,
                  max_new=5, seed=3):
    """Requests sharing a ``prefix_len``-token prefix (1 in 3 fully
    random); deterministic in ``seed`` so cache-on and cache-off runs see
    identical prompts."""
    rng = np.random.default_rng(seed)
    shared = np.random.default_rng(1000 + prefix_len).integers(
        0, cfg.vocab_size, size=prefix_len).astype(np.int32)
    reqs = []
    if lead_with_prefix:                             # inserts valid_end=prefix_len
        reqs.append(Request(prompt=shared.copy(), max_new_tokens=max_new))
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size, size=4 + i % 4).astype(np.int32)
        if i % 3 == 0 and not lead_with_prefix:
            p = rng.integers(0, cfg.vocab_size,
                             size=prefix_len + 4 + i % 4).astype(np.int32)
        else:
            p = np.concatenate([shared, tail])
        reqs.append(Request(prompt=p, max_new_tokens=max_new))
    return reqs


def _streams(engine, reqs):
    return [list(r.out_tokens) for r in engine.run(reqs)]


class TestEngineParity:
    def test_ragged_global_bit_identical_with_hits(self, qwen):
        cfg, params = qwen
        base = _streams(ServeEngine(cfg, params, batch_size=3, max_seq=96),
                        _shared_trace(cfg))
        pc = RadixPrefixCache(block_size=8, capacity_blocks=64)
        on = ServeEngine(cfg, params, batch_size=3, max_seq=96,
                         prefix_cache=pc)
        served = on.run(_shared_trace(cfg))
        assert [list(r.out_tokens) for r in served] == base
        s = pc.stats()
        assert s["cached_tokens"] > 0 and s["hits"] > 0
        assert any(r.cached_prefill > 0 for r in served)

    def test_second_identical_prefix_wave_hits(self, qwen):
        cfg, params = qwen
        pc = RadixPrefixCache(block_size=8, capacity_blocks=64)
        on = ServeEngine(cfg, params, batch_size=3, max_seq=96,
                         prefix_cache=pc)
        base = _streams(ServeEngine(cfg, params, batch_size=3, max_seq=96),
                        _shared_trace(cfg, seed=8))
        assert _streams(on, _shared_trace(cfg, seed=8)) == base
        first = pc.stats()["cached_tokens"]
        # the re-run re-prefills the SAME prompts: every shared prefix hits
        assert _streams(on, _shared_trace(cfg, seed=8)) == base
        assert pc.stats()["cached_tokens"] > first

    def test_windowed_hit_parity(self, gemma):
        """Sliding-window rings reuse a prefix only when its blocks were
        extracted at a valid_end the window can still see — the
        lead-with-prefix trace guarantees that, and streams stay exact."""
        cfg, params = gemma
        kw = dict(prefix_len=40, lead_with_prefix=True, n=5)
        base = _streams(ServeEngine(cfg, params, batch_size=2, max_seq=96),
                        _shared_trace(cfg, **kw))
        pc = RadixPrefixCache(block_size=8, capacity_blocks=64)
        on = ServeEngine(cfg, params, batch_size=2, max_seq=96,
                         prefix_cache=pc)
        assert _streams(on, _shared_trace(cfg, **kw)) == base
        assert pc.stats()["cached_tokens"] > 0

    def test_windowed_truncation_still_exact(self, gemma):
        """Prefix blocks extracted from LONGER prompts hold ring garbage
        for windowed layers; the match must truncate (here: to zero) and
        the streams must still be bit-identical."""
        cfg, params = gemma
        kw = dict(prefix_len=40, n=5)
        base = _streams(ServeEngine(cfg, params, batch_size=2, max_seq=96),
                        _shared_trace(cfg, **kw))
        pc = RadixPrefixCache(block_size=8, capacity_blocks=64)
        on = ServeEngine(cfg, params, batch_size=2, max_seq=96,
                         prefix_cache=pc)
        assert _streams(on, _shared_trace(cfg, **kw)) == base
        assert pc.stats()["cached_tokens"] == 0      # truncated, not corrupt

    @pytest.mark.parametrize("q", ["int8", "bf16"])
    def test_quantized_weights_parity(self, qwen, q):
        cfg0, params = qwen
        cfg = dataclasses.replace(cfg0, quantize=q)
        base = _streams(ServeEngine(cfg, params, batch_size=2, max_seq=96),
                        _shared_trace(cfg, n=5))
        pc = RadixPrefixCache(block_size=8, capacity_blocks=64)
        on = ServeEngine(cfg, params, batch_size=2, max_seq=96,
                         prefix_cache=pc)
        assert _streams(on, _shared_trace(cfg, n=5)) == base
        assert pc.stats()["cached_tokens"] > 0

    def test_warmup_drops_probe_blocks(self, qwen):
        cfg, params = qwen
        pc = RadixPrefixCache(block_size=8, capacity_blocks=64)
        engine = ServeEngine(cfg, params, batch_size=3, max_seq=96,
                             prefix_cache=pc)
        engine.warmup()
        s = pc.stats()
        assert s["requests"] == 0 and pc.blocks == 0


class TestEngineRefusals:
    def test_int8_kv_storage_refused(self, qwen):
        cfg0, params = qwen
        cfg = dataclasses.replace(cfg0, cache_dtype="int8")
        with pytest.raises(ValueError, match="cache_dtype"):
            ServeEngine(cfg, params, batch_size=2, max_seq=96,
                        prefix_cache=RadixPrefixCache())

    def test_non_ragged_stack_refused(self):
        cfg = get_smoke("mamba2-2.7b")               # recurrent: no ragged
        params, _ = init_model(jax.random.PRNGKey(2), cfg)
        with pytest.raises(ValueError, match="ragged"):
            ServeEngine(cfg, params, batch_size=2, max_seq=64,
                        prefix_cache=RadixPrefixCache())

    def test_rebind_different_layout_refused(self, qwen, gemma):
        cfg_q, params_q = qwen
        cfg_g, params_g = gemma
        pc = RadixPrefixCache(block_size=8, capacity_blocks=16)
        ServeEngine(cfg_q, params_q, batch_size=2, max_seq=96,
                    prefix_cache=pc)
        with pytest.raises(ValueError, match="already bound"):
            ServeEngine(cfg_g, params_g, batch_size=2, max_seq=96,
                        prefix_cache=pc)


class TestFleet:
    def test_fleet_parity_and_shared_trie(self, qwen):
        cfg, params = qwen
        off = ReplicaRouter(cfg, params, slots_per_replica=2,
                            max_replicas=2, max_seq=96)
        base = sorted(tuple(r.out_tokens)
                      for r in off.run(_shared_trace(cfg, n=8)))
        pc = RadixPrefixCache(block_size=8, capacity_blocks=64)
        on = ReplicaRouter(cfg, params, slots_per_replica=2,
                           max_replicas=2, max_seq=96, prefix_cache=pc)
        on.warmup()
        assert pc.stats()["requests"] == 0           # warmup left no trace
        got = sorted(tuple(r.out_tokens)
                     for r in on.run(_shared_trace(cfg, n=8)))
        assert got == base
        rep = on.report()
        assert rep["prefix_cache"]["cached_tokens"] > 0
        # a prefix prefilled by one replica's lane hits for the other:
        # more hit requests than any single 2-slot replica admitted waves
        assert rep["prefix_cache"]["hits"] > 0

    def test_scheduler_tenant_hit_rate_accounting(self, qwen):
        cfg, params = qwen
        pc = RadixPrefixCache(block_size=8, capacity_blocks=64)
        on = ReplicaRouter(cfg, params, slots_per_replica=2,
                           max_replicas=1, max_seq=96, prefix_cache=pc)
        reqs = _shared_trace(cfg, n=6)
        for r in reqs:
            r.tenant = "acme"
        on.run(reqs)
        t = on.report()["tenants"]["acme"]
        assert t["prefill_tokens"] == sum(len(r.prompt) for r in reqs)
        assert t["cached_prefill_tokens"] > 0
        assert 0.0 < t["prefix_hit_rate"] < 1.0


# --------------------------------------------------------------------------- #
# predictor featurize memo (satellite: classical twin of the prefix cache)
# --------------------------------------------------------------------------- #
class TestFeaturizeMemo:
    @staticmethod
    def _service(cache=512):
        calls = {"rows": 0}

        def featurize(rows):
            calls["rows"] += len(rows)
            return np.stack([np.full(3, float(len(r)), np.float32)
                             for r in rows])

        svc = ModelPredictor(model=None, max_batch=4,
                             predict_fn=lambda X: X.sum(axis=1),
                             featurize=featurize, featurize_cache=cache)
        return svc, calls

    def test_repeated_rows_skip_featurizer(self):
        svc, calls = self._service()
        svc.submit(PredictRequest(features=np.asarray(["ab", "cde"], object)))
        svc.flush()
        assert calls["rows"] == 2
        svc.submit(PredictRequest(features=np.asarray(["ab", "cde"], object)))
        out = svc.flush()
        assert calls["rows"] == 2                    # all hits, no new calls
        np.testing.assert_allclose(out[0].result, [6.0, 9.0])
        assert svc.featurize_hits == 2 and svc.featurize_misses == 2

    def test_within_flush_duplicates_featurized_once(self):
        svc, calls = self._service()
        svc.submit(PredictRequest(features=np.asarray(["x", "x", "yy"],
                                                      object)))
        out = svc.flush()
        assert calls["rows"] == 2                    # "x" featurized once
        np.testing.assert_allclose(out[0].result, [3.0, 3.0, 6.0])

    def test_memo_off_matches_memo_on(self):
        rows = np.asarray(["aa", "b", "aa", "ccc"], object)
        on, _ = self._service(cache=512)
        off, calls_off = self._service(cache=0)
        on.submit(PredictRequest(features=rows.copy()))
        off.submit(PredictRequest(features=rows.copy()))
        r_on, r_off = on.flush()[0].result, off.flush()[0].result
        np.testing.assert_array_equal(r_on, r_off)
        assert off._feat_memo is None and calls_off["rows"] == 4

    def test_lru_bound_and_eviction(self):
        svc, calls = self._service(cache=2)
        for batch in (["a", "b"], ["c"], ["a"]):     # "a" evicted by "c"
            svc.submit(PredictRequest(features=np.asarray(batch, object)))
            svc.flush()
        assert len(svc._feat_memo) <= 2
        assert calls["rows"] == 4                    # "a" re-featurized
        assert svc.report()["featurize_misses"] == 4
