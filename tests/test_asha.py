"""Asynchronous successive halving: scheduler invariants + the search driver.

Three layers:

  * :class:`AshaScheduler` unit behavior — slot-order decisions, FIFO
    backfill, budget-gated admission, state_dict round-trip;
  * hypothesis properties of random search traces — the rung ledger, slot
    table, and terminal set stay consistent no matter the score sequence;
  * the in-process :class:`ModelSearch` driver — backfilled trials, the
    stacked/sequential promotion parity, rung-for-rung checkpoint resume,
    and early-stop drain.

The 8-device mesh determinism run (all three collective schedules,
fp-equal scores) is the slow twin in ``test_tune_determinism.py`` /
``test_tune_resume.py``; this file is tier-1 fast.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.numeric_table import MLNumericTable
from repro.tune import (AshaScheduler, AsyncSuccessiveHalving, ModelSearch,
                        grid)


def mk_sched(n=6, epochs=9, slots=2, rf=3, min_rounds=1, budget=None):
    rule = AsyncSuccessiveHalving(reduction_factor=rf, min_rounds=min_rounds,
                                  slots=slots, epoch_budget=budget)
    return AshaScheduler(rule, n, epochs, slots)


# --------------------------------------------------------------------------- #
# rule
# --------------------------------------------------------------------------- #
def test_rung_ladder_is_geometric_and_ends_at_budget():
    rule = AsyncSuccessiveHalving(reduction_factor=3, min_rounds=1)
    assert rule.rung_epochs(9) == [1, 3, 9]
    assert rule.rung_epochs(10) == [1, 3, 9, 10]
    assert rule.rung_epochs(2) == [1, 2]
    # min_rounds at or past the budget: a single finish-line rung
    assert AsyncSuccessiveHalving(min_rounds=8).rung_epochs(8) == [8]


def test_rule_validates_parameters():
    with pytest.raises(ValueError, match="reduction_factor"):
        AsyncSuccessiveHalving(reduction_factor=1)
    with pytest.raises(ValueError, match="min_rounds"):
        AsyncSuccessiveHalving(min_rounds=0)
    with pytest.raises(ValueError, match="slots"):
        AsyncSuccessiveHalving(slots=0)


def test_promotion_is_top_quantile_of_reports_so_far():
    rule = AsyncSuccessiveHalving(reduction_factor=2)
    # first report always promotes (it IS the top half of itself)
    assert rule.promote(0.1, [0.1])
    # median cut with rf=2
    assert rule.promote(0.9, [0.5, 0.7, 0.9])
    assert not rule.promote(0.5, [0.5, 0.7, 0.9])


# --------------------------------------------------------------------------- #
# scheduler transitions
# --------------------------------------------------------------------------- #
def test_admit_backfills_fifo_and_tracks_slots():
    sched = mk_sched(n=5, slots=2)
    assert sched.admit() == [(0, 0), (1, 1)]
    assert sched.pending == [2, 3, 4]
    sched.advance(1)
    # trial 0 reports high (promoted), trial 1 low (stopped, slot freed)
    assert sched.report(0, 1.0) is True
    assert sched.report(1, 0.0) is False
    assert sched.terminal[1] == "stopped"
    # the freed slot backfills the FIFO head, not an arbitrary pending id
    assert sched.admit() == [(1, 2)]


def test_due_and_tick_follow_the_rung_ladder():
    sched = mk_sched(n=2, epochs=9, slots=2)
    sched.admit()
    assert sched.tick_size() == 1            # first rung at epoch 1
    sched.advance(1)
    assert sched.due() == [(0, 0), (1, 1)]   # slot order
    # equal scores: both sit at the quantile cut, both promote
    assert sched.report(0, 1.0) is True
    assert sched.report(1, 1.0) is True
    assert sched.tick_size() == 2            # both promoted: rung 3 is 2 away
    sched.advance(2)
    sched.report(0, 1.0)
    sched.report(1, 1.0)
    assert sched.tick_size() == 6            # final rung at 9
    sched.advance(6)
    assert sched.report(0, 1.0) is False     # finish line frees the slot
    assert sched.terminal[0] == "done"


def test_mixed_rungs_tick_to_the_nearest_deadline():
    """Slots sitting at different local epochs advance by the MINIMUM
    remaining segment, so no trial overshoots its rung."""
    sched = mk_sched(n=4, epochs=9, slots=2)
    sched.admit()
    sched.advance(1)
    sched.report(0, 1.0)                     # promoted -> next rung at 3
    sched.report(1, 0.0)                     # stopped
    sched.admit()                            # trial 2 enters at local 0
    # slot 0 needs 2 more epochs, slot 1 needs 1 -> tick is 1
    assert sched.tick_size() == 1
    sched.advance(1)
    assert sched.due() == [(1, 2)]           # only the fresh trial is due


def test_budget_gates_admission_but_not_running_trials():
    sched = mk_sched(n=6, slots=2, budget=4)
    sched.admit()
    sched.advance(1)                         # 2 slot-epochs spent
    assert sched.report(0, 1.0) is True      # promoted
    assert sched.report(1, 0.0) is False     # stopped, slot freed
    sched.advance(2)                         # trial 0 alone: meter hits 4
    assert sched.exhausted()
    assert sched.admit() == []               # budget spent: no backfill
    assert not sched.finished()              # trial 0 still drains
    assert sched.report(0, 1.0) is True      # rung-3 promote past the meter
    sched.advance(6)
    assert sched.report(0, 1.0) is False     # finish line
    assert sched.finished()                  # slots empty, budget spent
    assert sched.pending                     # trials 2..5 never admitted


def test_state_dict_roundtrip_mid_rung():
    sched = mk_sched(n=5, slots=2)
    sched.admit()
    sched.advance(1)
    sched.report(0, 0.9)
    sched.report(1, 0.2)
    sched.admit()
    rule = sched.rule
    clone = AshaScheduler.from_state_dict(rule, 9, sched.state_dict())
    assert clone.slots == sched.slots
    assert clone.pending == sched.pending
    assert clone.local_epoch == sched.local_epoch
    assert clone.next_rung == sched.next_rung
    assert clone.rung_scores == sched.rung_scores
    assert clone.rung_trials == sched.rung_trials
    assert clone.terminal == sched.terminal
    assert clone.slot_epochs == sched.slot_epochs
    assert clone.global_epoch == sched.global_epoch


def test_from_state_dict_refuses_mismatched_ladder():
    sched = mk_sched(rf=3)
    state = sched.state_dict()
    other = AsyncSuccessiveHalving(reduction_factor=2)
    with pytest.raises(ValueError, match="rung ladder"):
        AshaScheduler.from_state_dict(other, 9, state)


# --------------------------------------------------------------------------- #
# properties: random traces keep the invariants
# --------------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=12),
    slots=st.integers(min_value=1, max_value=4),
    epochs=st.integers(min_value=1, max_value=12),
    rf=st.integers(min_value=2, max_value=4),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_random_trace_invariants(n, slots, epochs, rf, seed):
    """Drive a scheduler with random scores to completion and check:
    every trial terminates exactly once (no budget => whole pool runs);
    rung populations shrink monotonically; per-rung promotion count
    matches the rule applied report-by-report; local epochs of reports
    equal the rung ladder; slots empty at the end."""
    import random

    rng = random.Random(seed)
    rule = AsyncSuccessiveHalving(reduction_factor=rf, slots=slots)
    sched = AshaScheduler(rule, n, epochs, slots)
    rungs = sched.rungs
    reports = []  # (trial, rung_index, score, promoted)
    guard = 0
    while not sched.finished():
        guard += 1
        assert guard < 10_000, "scheduler failed to converge"
        sched.admit()
        if not sched.occupied():
            break
        delta = sched.tick_size()
        assert delta >= 1
        sched.advance(delta)
        for _, t in sched.due():
            rung = sched.next_rung[t]
            assert sched.local_epoch[t] == rungs[rung]
            score = rng.random()
            promoted = sched.report(t, score)
            reports.append((t, rung, score, promoted))

    assert sorted(sched.terminal) == list(range(n))
    assert not sched.occupied() and not sched.pending
    # rung populations shrink (never grow) up the ladder
    pops = [len(r) for r in sched.rung_trials]
    assert all(a >= b for a, b in zip(pops, pops[1:]))
    assert pops[0] == n
    # replay the ledger: each decision must match the rule at report time
    so_far = [[] for _ in rungs]
    for t, rung, score, promoted in reports:
        so_far[rung].append(score)
        want = (rung < len(rungs) - 1
                and rule.promote(score, so_far[rung]))
        assert promoted == want
    # every terminal trial's last rung matches its status
    for t, status in sched.terminal.items():
        hist = [r for tr, r, _, _ in reports if tr == t]
        assert status == ("done" if hist[-1] == len(rungs) - 1 else "stopped")


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=10),
    slots=st.integers(min_value=1, max_value=4),
    budget=st.integers(min_value=1, max_value=30),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_budget_property(n, slots, budget, seed):
    """With an epoch budget: admission stops once spent, running trials
    drain to a decision, and total slot-epochs overshoot the budget by at
    most slots * (the largest remaining segment)."""
    import random

    rng = random.Random(seed)
    rule = AsyncSuccessiveHalving(reduction_factor=2, slots=slots,
                                  epoch_budget=budget)
    sched = AshaScheduler(rule, n, 8, slots)
    admitted = set()
    while not sched.finished():
        for _, t in sched.admit():
            assert sched.slot_epochs < budget  # never admit past the meter
            admitted.add(t)
        if not sched.occupied():
            break
        sched.advance(sched.tick_size())
        for _, t in sched.due():
            sched.report(t, rng.random())
    assert set(sched.terminal) == admitted
    # after the meter crosses the budget, only the <= slots occupants keep
    # running, each for at most its full trial budget of 8 epochs
    assert sched.slot_epochs <= budget + slots * 8


# --------------------------------------------------------------------------- #
# the driver (emulated partitions, in-process — fast)
# --------------------------------------------------------------------------- #
ROWS, D = 192, 4


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(0)
    w = rng.normal(size=D)
    X = rng.normal(size=(ROWS, D)).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    return MLNumericTable.from_numpy(np.column_stack([y, X]))


CONFIGS = grid({"learning_rate": [0.02, 0.1, 0.5, 1.0], "l2": [0.0, 0.01]})


def search(execution="auto", slots=4, ckpt=None, cb=None, budget=None,
           callbacks=()):
    return ModelSearch(
        algorithm="logreg", configs=CONFIGS, num_epochs=9,
        chunks_per_epoch=2, execution=execution,
        early_stop=AsyncSuccessiveHalving(reduction_factor=3, min_rounds=1,
                                          slots=slots, epoch_budget=budget),
        callbacks=callbacks, ckpt_dir=ckpt, unit_callback=cb, seed=0)


def test_asha_runs_whole_pool_with_backfill(table):
    res = search().run(table)
    assert len(res.trials) == len(CONFIGS)   # slots=4 < 8 trials: backfill
    assert all(t.rung_scores for t in res.trials)
    # stopped trials have strictly fewer rung looks than finishers
    finished = [t for t in res.trials if not t.stopped]
    stopped = [t for t in res.trials if t.stopped]
    assert finished and stopped
    assert all(len(t.rung_scores) == 3 for t in finished)
    assert all(len(t.rung_scores) < 3 for t in stopped)
    assert res.best.index in [t.index for t in finished]


def test_asha_stacked_equals_sequential(table):
    """The same host-side scheduler drives both executions: promotion
    sequence identical, scores fp-equal."""
    a = search("auto").run(table)
    b = search("sequential").run(table)
    assert [(t.index, len(t.rung_scores), t.stopped) for t in a.trials] == \
           [(t.index, len(t.rung_scores), t.stopped) for t in b.trials]
    for ta, tb in zip(a.trials, b.trials):
        np.testing.assert_allclose(ta.rung_scores, tb.rung_scores, atol=1e-5)


def test_asha_budget_limits_admission(table):
    res = search(budget=12).run(table)       # 8 trials don't all fit
    assert 0 < len(res.trials) < len(CONFIGS)


def test_asha_resume_is_rung_for_rung(table, tmp_path):
    """Kill at every decision batch in turn; each resume must reproduce
    the uninterrupted search — same promotions, same scores, same final
    weights."""
    ref = search().run(table)

    class Kill(Exception):
        pass

    kill_at = 1
    while True:
        ckpt = str(tmp_path / f"k{kill_at}")
        calls = {"n": 0}

        def killer(done, newly):
            calls["n"] += 1
            if calls["n"] == kill_at:
                raise Kill()

        try:
            search(ckpt=ckpt, cb=killer).run(table)
            break                            # ran to completion: done
        except Kill:
            pass
        res = search(ckpt=ckpt).run(table, resume=True)
        assert [(t.index, t.stopped) for t in res.trials] == \
               [(t.index, t.stopped) for t in ref.trials]
        for ta, tb in zip(ref.trials, res.trials):
            np.testing.assert_allclose(ta.rung_scores, tb.rung_scores,
                                       atol=1e-6)
            np.testing.assert_allclose(np.asarray(ta.state),
                                       np.asarray(tb.state), atol=1e-6)
        kill_at += 1
    assert kill_at > 2                       # actually exercised mid-search


def test_asha_search_early_stop_callback_drains(table):
    """A rung-boundary early_stopping halt ends the search: already-scored
    running trials are recorded as stopped, unadmitted ones are absent."""
    from repro.tune import early_stopping

    res = search(callbacks=(early_stopping(1),)).run(table)
    assert 0 < len(res.trials) <= len(CONFIGS)
    assert all(t.rung_scores for t in res.trials)


def test_asha_rejects_pipeline_search(table):
    from repro.features import Standardizer
    from repro.pipeline import Pipeline

    ms = ModelSearch(
        algorithm=Pipeline([Standardizer()]), configs=CONFIGS,
        early_stop=AsyncSuccessiveHalving())
    with pytest.raises(NotImplementedError, match="ASHA"):
        ms.run(table)


def test_asha_fingerprint_separates_rules(table):
    """A median-rule checkpoint must not resume an ASHA search: the rule
    is part of the search fingerprint."""
    med = ModelSearch(algorithm="logreg", configs=CONFIGS, num_epochs=9,
                      chunks_per_epoch=2, seed=0)
    asha = search()
    assert med._fingerprint(table) != asha._fingerprint(table)
