"""Continuous-batching serving: scheduler admission/backfill, ragged
prefill, decode edge cases, and the prediction service.

The load-bearing invariant throughout: the continuous engine's greedy
token stream is IDENTICAL per request to the slot-at-a-time reference
(``ServeEngine._run_one``) — mixed prompt lengths, mid-decode backfill,
ring caches, and the per-request fallback for recurrent stacks included.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.transformer import TransformerLM, init_model
from repro.serve import (ModelPredictor, PredictRequest, Request, ServeEngine,
                         SlotScheduler)

KEY = jax.random.PRNGKey(1)


def make_requests(cfg, lens, news, seed=42, eos_id=None):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n)
                    .astype(np.int32), max_new_tokens=m, eos_id=eos_id)
            for n, m in zip(lens, news)]


@pytest.fixture(scope="module")
def qwen_engine():
    cfg = get_smoke("qwen2-1.5b")                    # dense GQA
    params, _ = init_model(KEY, cfg)
    return ServeEngine(cfg, params, batch_size=3, max_seq=96)


@pytest.fixture(scope="module")
def gemma_engine():
    cfg = get_smoke("gemma3-1b")                     # sliding-window ring cache
    params, _ = init_model(KEY, cfg)
    return ServeEngine(cfg, params, batch_size=3, max_seq=96)


def reference(engine, reqs):
    return [engine._run_one(Request(prompt=r.prompt.copy(),
                                    max_new_tokens=r.max_new_tokens,
                                    eos_id=r.eos_id)) for r in reqs]


# --------------------------------------------------------------------------- #
# scheduler (host-side, no jax)
# --------------------------------------------------------------------------- #
def test_scheduler_fifo_admission_and_backfill():
    sched = SlotScheduler(2)
    reqs = [Request(prompt=np.zeros(4, np.int32)) for _ in range(4)]
    for r in reqs:
        sched.submit(r)
    admits = sched.admit(0.0)
    assert [r for _, r in admits] == reqs[:2]        # FIFO into both slots
    assert sched.backfills == 0                      # nothing was mid-decode
    assert sched.queued() == 2 and sched.busy == 2
    sched.retire(0, 1.0)
    admits = sched.admit(1.0)                        # slot 1 still decoding
    assert [s for s, _ in admits] == [0] and admits[0][1] is reqs[2]
    assert sched.backfills == 1                      # counted as backfill
    sched.retire(0, 2.0)
    sched.retire(1, 2.0)
    sched.admit(2.0)
    assert sched.busy == 1 and not sched.queued()
    sched.retire(0, 3.0)
    assert not sched.has_work()
    rep = sched.report()
    assert rep["retired"] == 4 and rep["queue_depth_max"] == 2


def test_scheduler_holds_future_arrivals():
    sched = SlotScheduler(2)
    early = Request(prompt=np.zeros(4, np.int32), arrival=0.0)
    late = Request(prompt=np.zeros(4, np.int32), arrival=5.0)
    sched.submit(late)
    sched.submit(early)
    admits = sched.admit(1.0)
    assert [r for _, r in admits] == [early]         # late not yet released
    assert sched.next_arrival() == 5.0
    sched.retire(0, 2.0)
    assert [r for _, r in sched.admit(6.0)] == [late]
    assert early.admitted_at == 1.0 and late.admitted_at == 6.0


def test_scheduler_burst_release_is_arrival_fifo():
    """Regression: a burst trace submitted out of arrival order used to be
    released in *submission* order, letting a later-arriving request jump
    the queue when one ``release(now)`` covered several arrivals.  Release
    order must be ``(arrival, submission seq)`` — and stable for equal
    arrivals."""
    sched = SlotScheduler(1)
    arrivals = [3.0, 1.0, 2.0, 1.0, 0.0]             # submitted out of order
    reqs = [Request(prompt=np.zeros(4, np.int32), arrival=a)
            for a in arrivals]
    for r in reqs:
        sched.submit(r)
    # one release covering the whole burst: strict arrival order, with the
    # two arrival=1.0 requests kept in submission order (seq 1 before 3)
    order = []
    now = 10.0
    while sched.queued() or sched.busy:
        for slot, r in sched.admit(now):
            order.append(r)
            sched.retire(slot, now)
        now += 1.0
    assert order == [reqs[4], reqs[1], reqs[3], reqs[2], reqs[0]]
    assert [r.seq for r in order] == [4, 1, 3, 2, 0]


def test_scheduler_incremental_release_matches_burst_release():
    """The same trace released in many small ``admit`` calls (clock moving
    past each arrival) must admit in the same global order as one big
    release — FIFO cannot depend on the polling cadence."""
    arrivals = [0.5, 2.5, 1.5, 2.5, 0.5, 3.5]

    def drain(step):
        sched = SlotScheduler(1)
        reqs = [Request(prompt=np.zeros(4, np.int32), arrival=a)
                for a in arrivals]
        for r in reqs:
            sched.submit(r)
        order, now = [], 0.0
        while sched.has_work():
            for slot, r in sched.admit(now):
                order.append(r.seq)
                sched.retire(slot, now)
            now += step
        return order

    assert drain(0.25) == drain(100.0)


def test_engine_rejects_future_arrivals_on_frozen_clock(qwen_engine):
    req = Request(prompt=np.zeros(4, np.int32), max_new_tokens=1, arrival=9.9)
    with pytest.raises(ValueError, match="advancing clock"):
        qwen_engine.run([req])


# --------------------------------------------------------------------------- #
# continuous decode parity (the tentpole invariant)
# --------------------------------------------------------------------------- #
def test_mixed_lengths_with_backfill_match_slot_at_a_time(qwen_engine):
    """5 mixed-length requests through 3 slots: admission waves, staggered
    retirement, and mid-decode backfill — token streams must equal the
    slot-at-a-time reference exactly."""
    cfg = qwen_engine.cfg
    reqs = make_requests(cfg, (5, 9, 12, 7, 14), (3, 8, 5, 6, 4))
    sched = SlotScheduler(qwen_engine.batch)
    served = qwen_engine.run(reqs, scheduler=sched)
    for got, want in zip(served, reference(qwen_engine, reqs)):
        assert got.done and got.out_tokens == want.out_tokens
    assert sched.backfills > 0                       # truly mid-decode
    assert sched.report()["retired"] == len(reqs)


def test_sliding_window_arch_parity(gemma_engine):
    """Ring caches + ragged right-padded prefill: pad columns must never
    leak into the window (drop-mode cache writes)."""
    cfg = gemma_engine.cfg
    reqs = make_requests(cfg, (6, 11, 15, 8), (5, 4, 6, 3))
    served = gemma_engine.run(reqs)
    for got, want in zip(served, reference(gemma_engine, reqs)):
        assert got.out_tokens == want.out_tokens


def test_recurrent_arch_per_request_fallback_parity():
    """RG-LRU/SSD state would absorb a pad tail, so those stacks prefill
    per-request into the shared cache — fused per-slot decode still runs
    and must match slot-at-a-time."""
    cfg = get_smoke("mamba2-2.7b")
    params, _ = init_model(KEY, cfg)
    engine = ServeEngine(cfg, params, batch_size=2, max_seq=64)
    assert not engine.ragged_ok
    reqs = make_requests(cfg, (6, 11, 8), (4, 3, 5), seed=9)
    served = engine.run(reqs)
    for got, want in zip(served, reference(engine, reqs)):
        assert got.out_tokens == want.out_tokens


def test_prefill_ragged_rejects_recurrent_stacks():
    cfg = get_smoke("mamba2-2.7b")
    params, _ = init_model(KEY, cfg)
    model = TransformerLM(cfg)
    cache = model.init_cache(2, 32)
    toks = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="attention-only"):
        model.prefill_ragged(params, toks, jnp.asarray([4, 8]), cache)


def test_ragged_prefill_logits_match_batch1(qwen_engine):
    """Model-level check under the engine tests: per-slot last-token logits
    of one right-padded ragged prefill equal each prompt's own batch-1
    prefill."""
    cfg, model, params = qwen_engine.cfg, qwen_engine.model, qwen_engine.params
    rng = np.random.default_rng(3)
    lens = [5, 9, 12]
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    padded = np.zeros((3, max(lens)), np.int32)
    for i, p in enumerate(prompts):
        padded[i, : len(p)] = p
    cache = model.init_cache(3, 64)
    ragged, _ = model.prefill_ragged(params, jnp.asarray(padded),
                                     jnp.asarray(lens), cache)
    for i, p in enumerate(prompts):
        one, _ = model.prefill(params, jnp.asarray(p)[None, :],
                               model.init_cache(1, 64))
        np.testing.assert_allclose(
            np.asarray(ragged[i, 0], np.float32),
            np.asarray(one[0, -1], np.float32), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# decode edge cases
# --------------------------------------------------------------------------- #
def test_eos_on_first_generated_token(qwen_engine):
    cfg = qwen_engine.cfg
    probe = make_requests(cfg, (10,), (1,), seed=5)
    first = reference(qwen_engine, probe)[0].out_tokens[0]
    reqs = make_requests(cfg, (10, 13), (6, 6), seed=5, eos_id=int(first))
    served = qwen_engine.run(reqs)
    assert served[0].out_tokens[0] == first and served[0].done
    assert served[0].out_tokens == reference(qwen_engine, reqs)[0].out_tokens
    assert served[1].out_tokens == reference(qwen_engine, reqs)[1].out_tokens


def test_max_new_tokens_zero_and_one(qwen_engine):
    cfg = qwen_engine.cfg
    reqs = make_requests(cfg, (8, 12, 9), (0, 1, 4))
    served = qwen_engine.run(reqs)
    assert served[0].out_tokens == [] and served[0].done
    refs = reference(qwen_engine, reqs)
    assert [len(r.out_tokens) for r in served] == [0, 1, 4]
    for got, want in zip(served, refs):
        assert got.out_tokens == want.out_tokens


def test_prompt_overflow_raises(qwen_engine):
    reqs = make_requests(qwen_engine.cfg, (90,), (10,))  # 90 + 10 > max_seq 96
    with pytest.raises(ValueError, match="max_seq"):
        qwen_engine.run(reqs)


def test_static_reference_still_groups_equal_lengths(qwen_engine):
    """run_static keeps the pre-refactor baseline semantics (used by
    benchmarks/serving_throughput.py) and matches the reference too."""
    cfg = qwen_engine.cfg
    reqs = make_requests(cfg, (8, 16, 8, 16, 24), (4, 4, 4, 4, 4))
    served = qwen_engine.run_static(reqs)
    for got, want in zip(served, reference(qwen_engine, reqs)):
        assert got.done and got.out_tokens == want.out_tokens


# --------------------------------------------------------------------------- #
# mesh placement (slot sharding; trivial 1-device mesh in tier-1, the
# 8-device version runs in the slow suite below)
# --------------------------------------------------------------------------- #
def test_engine_under_serving_mesh_smoke():
    from repro.launch.mesh import host_serving_setup

    cfg = get_smoke("qwen2-1.5b")
    params, axes = init_model(KEY, cfg)
    mesh, rules = host_serving_setup(cfg)
    engine = ServeEngine(cfg, params, batch_size=2, max_seq=64,
                         mesh=mesh, rules=rules, param_axes=axes)
    reqs = make_requests(cfg, (6, 9), (3, 3))
    served = engine.run(reqs)
    for got, want in zip(served, reference(engine, reqs)):
        assert got.out_tokens == want.out_tokens


@pytest.mark.slow
def test_slot_sharding_on_eight_devices(eight_device_run):
    """The shared cache's slot axis shards over an 8-device data axis and
    the served tokens still match the unsharded engine."""
    program = """
import json
import jax, numpy as np
from repro.configs import get_smoke
from repro.models.transformer import init_model
from repro.launch.mesh import host_serving_setup
from repro.serve import Request, ServeEngine

cfg = get_smoke("qwen2-1.5b")
params, axes = init_model(jax.random.PRNGKey(1), cfg)
mesh, rules = host_serving_setup(cfg)
assert mesh.devices.size == 8

def make():
    rng = np.random.default_rng(4)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
                    max_new_tokens=m)
            for n, m in zip((5, 9, 12, 7, 14, 6, 10, 8, 11, 13),
                            (3, 6, 4, 5, 3, 6, 4, 5, 3, 4))]

sharded = ServeEngine(cfg, params, batch_size=8, max_seq=64,
                      mesh=mesh, rules=rules, param_axes=axes)
plain = ServeEngine(cfg, params, batch_size=8, max_seq=64)
a = sharded.run(make())
b = plain.run(make())
match = all(x.out_tokens == y.out_tokens for x, y in zip(a, b))
print("RESULT::" + json.dumps({"match": match,
                               "toks": [x.out_tokens for x in a]}))
"""
    res = eight_device_run(program)
    assert res["match"]


# --------------------------------------------------------------------------- #
# prediction service (classic-ML side of the stack)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def kmeans_model():
    from repro.core.algorithms.kmeans import KMeans, KMeansParameters
    from repro.core.numeric_table import MLNumericTable

    rng = np.random.default_rng(1)
    X = (rng.normal(size=(64, 8)) + 4.0 * rng.integers(0, 3, size=(64, 1))
         ).astype(np.float32)
    table = MLNumericTable.from_numpy(X, num_shards=4)
    model = KMeans.train(table, KMeansParameters(k=3, max_iter=4))
    return model, X


def test_predictor_microbatches_split_and_rejoin(kmeans_model):
    model, X = kmeans_model
    service = ModelPredictor(model, max_batch=16)
    blocks = [X[:10], X[10:11], X[11:40], X[40:]]    # spans + tiny + short tail
    outs = service.predict_many(blocks)
    direct = np.asarray(model.predict(jnp.asarray(X)))
    np.testing.assert_array_equal(np.concatenate(outs), direct)
    rep = service.report()
    assert rep["batches"] == 4 and rep["rows_served"] == 64
    assert rep["rows_padded"] == 0                   # 64 rows = 4 full batches


def test_predictor_pads_short_final_batch(kmeans_model):
    model, X = kmeans_model
    service = ModelPredictor(model, max_batch=24)
    outs = service.predict_many([X[:50]])            # 50 = 24 + 24 + 2(+22 pad)
    np.testing.assert_array_equal(
        outs[0], np.asarray(model.predict(jnp.asarray(X[:50]))))
    assert service.report()["rows_padded"] == 22


def test_predictor_shard_aware_path(kmeans_model):
    model, X = kmeans_model
    sharded = ModelPredictor(model, max_batch=16, num_shards=4)
    plain = ModelPredictor(model, max_batch=16)
    a = sharded.predict_many([X[:16], X[16:48]])
    b = plain.predict_many([X[:16], X[16:48]])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    with pytest.raises(ValueError, match="divide"):
        ModelPredictor(model, max_batch=10, num_shards=4)


def test_predictor_serves_supervised_model():
    from repro.core.algorithms.logistic_regression import (
        LogisticRegressionAlgorithm, LogisticRegressionParameters)
    from repro.core.numeric_table import MLNumericTable

    rng = np.random.default_rng(2)
    w = np.linspace(-1, 1, 6).astype(np.float32)
    X = rng.normal(size=(48, 6)).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    table = MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                      num_shards=4)
    model = LogisticRegressionAlgorithm.train(
        table, LogisticRegressionParameters(max_iter=5))
    service = ModelPredictor(model, max_batch=16, num_shards=4)
    outs = service.predict_many([X[:5], X[5:31], X[31:]])
    np.testing.assert_array_equal(
        np.concatenate(outs), np.asarray(model.predict(jnp.asarray(X))))


def test_predictions_helper_concatenates_in_row_order(kmeans_model):
    from repro.core.numeric_table import MLNumericTable
    from repro.eval.metrics import predictions

    model, X = kmeans_model
    table = MLNumericTable.from_numpy(X, num_shards=4)
    got = predictions(table, model.predict)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(model.predict(jnp.asarray(X))))
