"""`repro.tune` emulated-mode semantics: stacked == sequential == the
existing single-model trainers, deterministic enumeration/grouping, the
median stopping rule, ALS trial stacking, and in-process search
checkpoint/resume.  (Mesh behavior — schedules x execution modes on a
real 8-device mesh, and SIGKILL resume through the CLI — lives in
`test_tune_determinism.py` / `test_tune_resume.py`.)"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.numeric_table import MLNumericTable
from repro.core.runner import DistributedRunner
from repro.tune import (
    MedianStoppingRule,
    ModelSearch,
    grid,
    sample,
)
from repro.tune.trials import SearchCheckpointer, group_trials, tree_stack, \
    tree_unstack


@pytest.fixture
def clf_table(rng):
    D = 6
    X = rng.normal(size=(96, D)).astype(np.float32)
    w = np.linspace(-1, 1, D).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    return MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                     num_shards=4)


GRID = {"learning_rate": [0.05, 0.3], "l2": [0.0, 0.01]}


# --------------------------------------------------------------------------- #
# enumeration + grouping
# --------------------------------------------------------------------------- #
def test_grid_enumeration_deterministic():
    a, b = grid(GRID), grid(GRID)
    assert a == b
    assert len(a) == 4
    # sorted-key cartesian order: l2-major, then learning_rate
    assert a[0] == {"l2": 0.0, "learning_rate": 0.05}
    assert a[-1] == {"l2": 0.01, "learning_rate": 0.3}


def test_grid_rejects_continuous_ranges():
    with pytest.raises(ValueError, match="sample"):
        grid({"learning_rate": ("loguniform", 0.01, 0.5)})


def test_sample_validates_range_bounds():
    with pytest.raises(ValueError, match="positive"):
        sample({"lr": ("loguniform", 0.0, 0.5)}, 2)
    with pytest.raises(ValueError, match="exceeds"):
        sample({"lr": ("uniform", 1.0, 0.5)}, 2)


def test_sample_deterministic_and_ranged():
    space = {"learning_rate": ("loguniform", 1e-3, 1.0), "l2": [0.0, 0.01]}
    a = sample(space, 8, seed=3)
    b = sample(space, 8, seed=3)
    assert a == b
    assert len(a) == 8
    for cfg in a:
        assert 1e-3 <= cfg["learning_rate"] <= 1.0
        assert cfg["l2"] in (0.0, 0.01)
    assert sample(space, 8, seed=4) != a


def test_group_trials_stacks_by_key_and_sequential_splits():
    from repro.core.algorithms.logistic_regression import \
        LogisticRegressionAlgorithm as LR

    configs = [{"learning_rate": 0.1},
               {"learning_rate": 0.3, "local_batch_size": 8},
               {"learning_rate": 0.2},
               {"l2": 0.01}]
    specs = [LR.trial_spec(c) for c in configs]
    groups = group_trials(specs, "auto")
    # batch-size-8 config is ragged; the rest share one stack
    assert groups == [[0, 2, 3], [1]]
    assert group_trials(specs, "sequential") == [[0], [1], [2], [3]]
    with pytest.raises(ValueError):
        group_trials(specs, "bogus")


def test_tree_stack_roundtrip():
    trees = [{"w": jnp.arange(3.0) * i, "b": jnp.asarray(float(i))}
             for i in range(4)]
    stacked = tree_stack(trees)
    assert stacked["w"].shape == (4, 3)
    back = tree_unstack(stacked)
    for orig, rec in zip(trees, back):
        np.testing.assert_array_equal(np.asarray(orig["w"]),
                                      np.asarray(rec["w"]))


# --------------------------------------------------------------------------- #
# stacked == sequential == the single-model trainer
# --------------------------------------------------------------------------- #
def test_stacked_matches_sequential_and_single_model(clf_table):
    """The acceptance property, emulated: every stacked trial's weights
    match both the sequential execution of the same search AND training
    that config alone through LogisticRegressionAlgorithm.train."""
    from repro.core.algorithms.logistic_regression import (
        LogisticRegressionAlgorithm, LogisticRegressionParameters)
    from repro.tune.cv import fold_view, holdout_split

    configs = grid(GRID)
    kw = dict(num_epochs=3, chunks_per_epoch=1, folds=None,
              val_fraction=0.25, seed=0)
    stacked = ModelSearch("logreg", configs, execution="stacked", **kw
                          ).run(clf_table)
    seq = ModelSearch("logreg", configs, execution="sequential", **kw
                      ).run(clf_table)

    assert [t.config for t in stacked.trials] == configs
    assert [t.config for t in seq.trials] == configs
    assert stacked.best.config == seq.best.config

    tr, _ = holdout_split(clf_table.num_rows, 0.25, seed=0)
    train_view = fold_view(clf_table, tr)
    for t_st, t_sq in zip(stacked.trials, seq.trials):
        assert t_st.score == pytest.approx(t_sq.score, abs=1e-5)
        np.testing.assert_allclose(np.asarray(t_st.state),
                                   np.asarray(t_sq.state), atol=1e-5)
        # one window, chunks_per_epoch=1: each epoch is exactly one
        # resident round, so the search reproduces .train() on the view
        solo = LogisticRegressionAlgorithm.train(
            train_view, LogisticRegressionParameters(
                max_iter=3, schedule="allreduce", **t_st.config))
        np.testing.assert_allclose(np.asarray(t_st.state),
                                   np.asarray(solo.weights), atol=1e-5)


def test_kmeans_search_with_ragged_k(rng):
    pts = np.concatenate([rng.normal(size=(48, 4)),
                          4 + rng.normal(size=(48, 4))]).astype(np.float32)
    table = MLNumericTable.from_numpy(pts, num_shards=4)
    configs = [{"k": 2, "seed": 0}, {"k": 2, "seed": 1}, {"k": 4, "seed": 0}]
    res = ModelSearch("kmeans", configs, num_epochs=5, folds=None,
                      seed=0).run(table)
    assert [t.config for t in res.trials] == configs
    # two well-separated blobs: k=2 wins on silhouette
    assert res.best.config["k"] == 2
    assert res.trials[0].state.shape == (2, 4)
    assert res.trials[2].state.shape == (4, 4)


def test_l1_config_stacks_with_unregularized(clf_table):
    """l1 rides as a traced soft-threshold — one stack group, and the
    l1=0 identity reproduces the prox-free single-model path."""
    configs = [{"learning_rate": 0.3}, {"learning_rate": 0.3, "l1": 0.05}]
    from repro.core.algorithms.logistic_regression import \
        LogisticRegressionAlgorithm as LR

    specs = [LR.trial_spec(c) for c in configs]
    assert group_trials(specs, "auto") == [[0, 1]]
    res = ModelSearch("logreg", configs, num_epochs=3, folds=None,
                      seed=0).run(clf_table)
    w_plain, w_l1 = (np.asarray(t.state) for t in res.trials)
    assert not np.allclose(w_plain, w_l1)
    # L1 shrinks: strictly smaller weight mass
    assert np.sum(np.abs(w_l1)) < np.sum(np.abs(w_plain))


# --------------------------------------------------------------------------- #
# median stopping
# --------------------------------------------------------------------------- #
def test_median_rule_unit():
    rule = MedianStoppingRule(min_rungs=1, min_trials=3)
    assert not rule.stop(0, 0.1, [0.9, 0.9, 0.9])     # warmup rung
    assert not rule.stop(1, 0.1, [0.9, 0.9])          # too few peers
    assert rule.stop(1, 0.1, [0.2, 0.5, 0.9])
    assert not rule.stop(1, 0.5, [0.2, 0.5, 0.9])     # at median: keep


def test_median_stopping_freezes_weak_trials(clf_table):
    configs = grid({"learning_rate": [1e-4, 1e-3, 0.3, 0.5]})
    res = ModelSearch("logreg", configs, num_epochs=4, folds=None,
                      execution="stacked", seed=0, rung_epochs=1,
                      early_stop=MedianStoppingRule(min_rungs=1, min_trials=2)
                      ).run(clf_table)
    by_lr = {t.config["learning_rate"]: t for t in res.trials}
    assert by_lr[1e-4].stopped and by_lr[1e-3].stopped
    assert not by_lr[0.3].stopped and not by_lr[0.5].stopped
    # stopped trials record fewer rungs and keep their last score
    assert len(by_lr[1e-4].rung_scores) < len(by_lr[0.3].rung_scores)
    assert by_lr[1e-4].score == by_lr[1e-4].rung_scores[-1]
    assert res.best.config["learning_rate"] in (0.3, 0.5)


# --------------------------------------------------------------------------- #
# search checkpoint/resume (in-process; SIGKILL variant in
# test_tune_resume.py)
# --------------------------------------------------------------------------- #
def test_search_resumes_trial_for_trial(clf_table, tmp_ckpt_dir):
    configs = grid({"learning_rate": [0.05, 0.1, 0.3], "l2": [0.0, 0.01]})
    kw = dict(num_epochs=3, folds=None, execution="sequential", seed=0)
    full = ModelSearch("logreg", configs, **kw).run(clf_table)

    class Interrupt(Exception):
        pass

    def bomb(units_done, trial_indices):
        if units_done == 2:
            raise Interrupt

    partial = ModelSearch("logreg", configs, ckpt_dir=tmp_ckpt_dir,
                          unit_callback=bomb, **kw)
    with pytest.raises(Interrupt):
        partial.run(clf_table)

    resumed = ModelSearch("logreg", configs, ckpt_dir=tmp_ckpt_dir, **kw
                          ).run(clf_table, resume=True)
    assert [t.config for t in resumed.trials] == [t.config for t in full.trials]
    for a, b in zip(full.trials, resumed.trials):
        assert a.score == pytest.approx(b.score, abs=1e-6)
        np.testing.assert_allclose(np.asarray(a.state), np.asarray(b.state),
                                   atol=1e-6)
    assert full.best.config == resumed.best.config


def test_resume_refuses_mismatched_search(clf_table, tmp_ckpt_dir):
    kw = dict(num_epochs=2, folds=None, execution="sequential", seed=0)
    configs = grid({"learning_rate": [0.1, 0.3]})
    ModelSearch("logreg", configs, ckpt_dir=tmp_ckpt_dir, **kw).run(clf_table)
    other = ModelSearch("logreg", grid({"learning_rate": [0.1, 0.5]}),
                        ckpt_dir=tmp_ckpt_dir, **kw)
    with pytest.raises(ValueError, match="fingerprint"):
        other.run(clf_table, resume=True)
    # the same search against DIFFERENT data must refuse too — resuming
    # would silently mix scores computed on incomparable tables
    bigger = MLNumericTable.from_numpy(
        np.concatenate([np.asarray(clf_table.data)] * 2), num_shards=4)
    with pytest.raises(ValueError, match="fingerprint"):
        ModelSearch("logreg", configs, ckpt_dir=tmp_ckpt_dir, **kw
                    ).run(bigger, resume=True)


def test_trials_carry_trained_models(clf_table):
    from repro.core.algorithms.logistic_regression import \
        LogisticRegressionModel

    res = ModelSearch("logreg", grid({"learning_rate": [0.1, 0.3]}),
                      num_epochs=2, folds=None, seed=0).run(clf_table)
    for t in res.trials:
        assert isinstance(t.model, LogisticRegressionModel)
        np.testing.assert_array_equal(np.asarray(t.model.weights),
                                      np.asarray(t.state))


def test_checkpointer_roundtrip(tmp_ckpt_dir):
    ck = SearchCheckpointer(tmp_ckpt_dir, "fp")
    states = {0: jnp.arange(3.0), 2: jnp.ones(3)}
    info = {0: {"score": 0.5, "rung_scores": [0.5], "stopped": False},
            2: {"score": 0.7, "rung_scores": [0.7], "stopped": True}}
    ck.save(states, info, units_done=2)
    got_states, got_info, units = ck.resume(lambda i: jnp.zeros(3))
    assert units == 2
    assert set(got_states) == {0, 2}
    np.testing.assert_array_equal(np.asarray(got_states[0]),
                                  np.arange(3.0))
    assert got_info[2]["stopped"] is True
    with pytest.raises(ValueError, match="fingerprint"):
        SearchCheckpointer(tmp_ckpt_dir, "fp2").resume(lambda i: jnp.zeros(3))


# --------------------------------------------------------------------------- #
# ALS trial stacking
# --------------------------------------------------------------------------- #
def test_als_stacked_matches_sequential(rng):
    from repro.core.algorithms.als import (ALSParameters, BroadcastALS,
                                           pack_csr_table)

    m, n, nnz = 24, 16, 120
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    vals = rng.normal(size=nnz).astype(np.float32)
    packed = pack_csr_table(rows, cols, vals, m, max_nnz=12, num_shards=4)
    packedT = pack_csr_table(cols, rows, vals, n, max_nnz=16, num_shards=4)
    ps = [ALSParameters(rank=4, lam=lam, max_iter=3, seed=seed)
          for lam, seed in [(0.01, 0), (0.1, 0), (0.01, 1)]]
    stacked = BroadcastALS.train_stacked(packed, ps, packedT)
    assert len(stacked) == 3
    for p, model in zip(ps, stacked):
        ref = BroadcastALS.train(packed, p, packedT)
        # vmapped solves reorder fp ops vs the solo path; 1e-3 is tight
        # for iterated normal-equation solves on random ratings
        np.testing.assert_allclose(np.asarray(model.U), np.asarray(ref.U),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(model.V), np.asarray(ref.V),
                                   atol=1e-3, rtol=1e-3)
    # differing lams produce genuinely different factorizations
    assert not np.allclose(np.asarray(stacked[0].U), np.asarray(stacked[1].U))


def test_als_stacked_rejects_ragged_structure(rng):
    from repro.core.algorithms.als import (ALSParameters, BroadcastALS,
                                           pack_csr_table)

    packed = pack_csr_table(np.asarray([0]), np.asarray([0]),
                            np.asarray([1.0], np.float32), 4, max_nnz=2)
    with pytest.raises(ValueError, match="rank"):
        BroadcastALS.train_stacked(
            packed, [ALSParameters(rank=2), ALSParameters(rank=3)], packed)


# --------------------------------------------------------------------------- #
# runner-level stacked entry points
# --------------------------------------------------------------------------- #
def test_run_stacked_rounds_matches_per_trial_rounds(clf_table):
    import jax

    def trial_step(block, w, r, h):
        X, y = block[:, 1:], block[:, 0]
        g = X.T @ (jax.nn.sigmoid(X @ w) - y) / X.shape[0]
        return w - h["lr"] * g

    runner = DistributedRunner(num_shards=4)
    d = clf_table.num_cols - 1
    lrs = jnp.asarray([0.05, 0.2, 0.4], jnp.float32)
    stacked = runner.run_stacked_rounds(
        clf_table, jnp.zeros((3, d)), {"lr": lrs}, trial_step, 6)
    for i in range(3):
        solo = runner.run_rounds(
            clf_table, jnp.zeros(d),
            lambda b, s, r, i=i: trial_step(b, s, r, {"lr": lrs[i]}), 6)
        np.testing.assert_allclose(np.asarray(stacked[i]), np.asarray(solo),
                                   atol=1e-6)
    # the active mask freezes exactly the masked trials
    frozen = runner.run_stacked_rounds(
        clf_table, jnp.zeros((3, d)), {"lr": lrs}, trial_step, 6,
        active=jnp.asarray([True, False, True]))
    assert np.allclose(np.asarray(frozen[1]), 0.0)
    np.testing.assert_allclose(np.asarray(frozen[0]), np.asarray(stacked[0]),
                               atol=1e-6)
